"""Pluggable data-plane backends for :class:`repro.fabric.Fabric`.

Every backend realises the same §IV-E interconnect contract — *plan* grant
decisions from the live register file, *dispatch* packets into destination
slabs, *combine* results back to packet order — and all of them are
plan-equivalent: identical ``keep``/``slot``/``error``/``counts`` for the
same packets and registers (property-tested against the dense oracle in
``tests/test_fabric.py``).

All three backends share the **scatter-native data plane** of
``repro.core.arbiter``: granted packets scatter straight into the flat
``dst * capacity + slot`` slab row with ``.at[addr].add`` and gather back
with ``jnp.take`` — O(T·D) bytes, no [T, S, C] selection tensor (the dense
one-hot/einsum formulations survive as ``arbiter.dispatch_dense`` /
``combine_dense``, test-only oracles).  What distinguishes the backends is
how the *plan* is computed and where the slabs live:

- ``reference`` — the pure-jnp plan oracle (``arbiter.wrr_dispatch_plan``:
  segment-cumsum stream ranks + the closed-form WRR slots).  The
  semantics ground truth.
- ``pallas``    — ONE fused multi-source plan kernel (``repro.kernels
  .crossbar_dispatch.ops._plan_multi``) grids over token blocks once and
  computes every (src, dst) stream's ranks and iso/quota verdicts in a
  single sweep — no per-master-port launches, no stacked [n, T]
  intermediates.  Ranks compose into global WRR slots with the shared
  closed form (``arbiter.wrr_slots``):

      slot(t) = sum_s' min(rank_t, granted[s', dst_t])
              + #{s' < src_t : granted[s', dst_t] > rank_t}

  which is exactly the lexicographic (round, source) position the rotating
  arbiter serves.  Token padding to the kernel block size is internal
  (``dst = -1`` rows drop via the isolation check).  Data movement uses
  the shared scatter path by default; ``data_plane="kernel"`` selects the
  historical blockwise MXU scatter/combine kernels instead.
- ``sharded``   — regions are shards of a mesh axis; dispatch scatters
  local packets into a flat send slab and ``all_to_all``s it, combine
  routes *addresses* across the axis (a second ``all_to_all`` pair) so
  each shard pulls exactly its own packets' result rows — bytes on the
  interconnect scale with packets, not with ``n_ports * capacity`` slabs.
  Methods must run inside ``shard_map`` over the axis; the per-source
  granted counts are ``all_gather``-ed so every shard computes the same
  global WRR slots the dense oracle assigns.  The register file's port
  space may be *larger* than the axis: ``n_ports`` destinations partition
  contiguously into ``n_ports // axis_size`` slave ports per shard (MoE
  expert parallelism: experts are slave ports, each shard owns an expert
  block), while source ids stay the axis indices.

Packets carry *values*, never shapes, from the register file — so an ERM
register rewrite re-routes traffic through already-compiled dispatch code.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core import arbiter
from repro.core.arbiter import DispatchPlan, wrr_slots
from repro.core.registers import CrossbarRegisters, ErrorCode
from repro.fabric.interface import KernelMode


def _empty_plan(dst: jax.Array, n_ports: int) -> DispatchPlan:
    """The zero-packet plan: no grants, empty histogram."""
    T = dst.shape[0]
    z = jnp.zeros((T,), jnp.int32)
    return DispatchPlan(keep=z.astype(bool), slot=z,
                        dst=dst.astype(jnp.int32), error=z,
                        counts=jnp.zeros((n_ports,), jnp.int32),
                        drops=jnp.zeros((4,), jnp.int32))


# The closed-form WRR interleave every backend composes slots with now
# lives beside the plan oracle; re-exported here for compatibility.
_wrr_slots = wrr_slots


# ----------------------------------------------------------------------
# reference — pure-jnp plan oracle + shared scatter data plane
# ----------------------------------------------------------------------
class ReferenceBackend:
    """The plan-semantics ground truth (``arbiter.wrr_dispatch_plan``),
    moving packets through the shared scatter/gather path.  The dense
    one-hot formulations it used to run live on as ``arbiter
    .dispatch_dense`` / ``combine_dense``, the property suite's oracles."""

    name = "reference"
    #: data movement is the shared flat-address scatter/gather — the
    #: fabric's plan cache may substitute memoized address vectors.
    uses_shared_scatter = True

    def plan(self, dst: jax.Array, src: jax.Array,
             regs: CrossbarRegisters) -> DispatchPlan:
        if dst.shape[0] == 0:
            return _empty_plan(dst, regs.n_ports)
        return arbiter.wrr_dispatch_plan(dst, src, regs)

    def dispatch(self, x: jax.Array, plan: DispatchPlan,
                 regs: CrossbarRegisters, capacity: int) -> jax.Array:
        return arbiter.dispatch(x, plan, regs.n_ports, capacity)

    def combine(self, y: jax.Array, plan: DispatchPlan,
                weights: jax.Array) -> jax.Array:
        return arbiter.combine(y, plan, weights)


# ----------------------------------------------------------------------
# pallas — blockwise kernels + closed-form WRR slot composition
# ----------------------------------------------------------------------
class PallasBackend:
    """Fused multi-source plan kernel + scatter-native data movement.

    ``plan`` is ONE kernel launch: a single grid sweep over token blocks
    computes every (src, dst) stream's ranks and iso/quota verdicts at
    once (``_plan_multi``), and the global WRR slots compose from the
    granted-count matrix with the shared closed form.  Padding and the
    zero-packet edge are handled here so callers never see block sizes or
    ``dst = -1`` rows.

    ``data_plane`` selects how packets move: ``"scatter"`` (default) is
    the shared flat-address scatter/gather of ``repro.core.arbiter`` —
    XLA-native dynamic scatter, O(T·D) bytes; ``"kernel"`` keeps the
    historical blockwise MXU one-hot kernels (scatter re-expressed as a
    matmul) for experimentation on hardware where that wins.
    """

    name = "pallas"

    def __init__(self, *, block_t: int = 256,
                 interpret: Optional[bool] = None,
                 data_plane: str = "scatter",
                 kernel_mode: Optional[KernelMode] = None):
        if data_plane not in ("scatter", "kernel"):
            raise ValueError(f"data_plane must be 'scatter' or 'kernel', "
                             f"got {data_plane!r}")
        self.block_t = block_t
        self.interpret = interpret
        self.data_plane = data_plane
        self.kernel_mode: Optional[KernelMode] = None
        self._force_ref = False
        if kernel_mode is not None:
            self.apply_kernel_mode(kernel_mode)

    def apply_kernel_mode(self, mode: KernelMode) -> None:
        """Bind a resolved :class:`~repro.fabric.interface.KernelMode` —
        called exactly once, by ``Fabric.__init__`` (or the constructor).

        The mode decides the kernel *lowering* behind the unchanged
        ``plan``/``dispatch``/``combine`` surface: ``PALLAS`` /
        ``PALLAS_INTERPRET`` pin ``interpret`` for every pallas_call;
        ``XLA`` routes the plan through its compiled ``lax.scan``
        reference (bit-identical by the pinned kernel-vs-ref sweeps) and
        the data plane through the shared scatter/gather.  An explicit
        legacy ``interpret=`` wins over the mode — it is the narrower,
        older contract."""
        self.kernel_mode = mode
        self._force_ref = mode is KernelMode.XLA
        if self.interpret is None and mode.uses_pallas:
            self.interpret = mode.interpret

    @property
    def uses_shared_scatter(self) -> bool:
        """True on the default scatter data plane (the fabric's plan cache
        may substitute memoized address vectors); the historical blockwise
        MXU kernels move data their own way.  ``KernelMode.XLA`` forces
        the shared path — it *is* the XLA lowering of the data plane."""
        return self.data_plane == "scatter" or self._force_ref

    def plan(self, dst: jax.Array, src: jax.Array,
             regs: CrossbarRegisters) -> DispatchPlan:
        from repro.kernels.crossbar_dispatch.ops import _plan_multi
        n = regs.n_ports
        T = dst.shape[0]
        if T == 0:
            return _empty_plan(dst, n)
        dst = dst.astype(jnp.int32)
        src = src.astype(jnp.int32)
        dstc = jnp.clip(dst, 0, n - 1)
        srcc = jnp.clip(src, 0, n - 1)
        # Fold reset gating into the isolation matrix the kernel consumes;
        # quota is stored [dst, src] in the register file, the kernel
        # indexes [src, dst].
        allowed_eff = (regs.allowed & ~regs.reset[:, None]
                       & ~regs.reset[None, :]).astype(jnp.int32)
        keep_pre, rank, err_pre, granted = _plan_multi(
            dst, src, allowed_eff, regs.quota.T, block_t=self.block_t,
            interpret=self.interpret, force_ref=self._force_ref)
        keep_pre = keep_pre > 0                              # iso & quota

        slot = wrr_slots(rank, granted, dstc, srcc[None, :])
        cap_ok = slot < regs.capacity[dstc]
        keep = keep_pre & cap_ok
        error = jnp.where(err_pre != ErrorCode.OK, err_pre,
                          jnp.where(cap_ok, jnp.int32(ErrorCode.OK),
                                    jnp.int32(ErrorCode.ACK_TIMEOUT)))
        counts = jnp.zeros((n,), jnp.int32).at[dstc].add(
            keep.astype(jnp.int32), mode="drop")
        drops = jnp.zeros((4,), jnp.int32).at[error].add(1, mode="drop")
        return DispatchPlan(keep=keep, slot=jnp.where(keep, slot, 0),
                            dst=dst, error=error, counts=counts, drops=drops)

    def dispatch(self, x: jax.Array, plan: DispatchPlan,
                 regs: CrossbarRegisters, capacity: int) -> jax.Array:
        if self.uses_shared_scatter:
            return arbiter.dispatch(x, plan, regs.n_ports, capacity)
        from repro.kernels.crossbar_dispatch.ops import \
            _dispatch as kernel_dispatch
        return kernel_dispatch(x, plan.dst, plan.keep.astype(jnp.int32),
                               plan.slot, n_ports=regs.n_ports,
                               capacity=capacity, block_t=self.block_t,
                               interpret=self.interpret)

    def combine(self, y: jax.Array, plan: DispatchPlan,
                weights: jax.Array) -> jax.Array:
        if self.uses_shared_scatter:
            return arbiter.combine(y, plan, weights)
        from repro.kernels.crossbar_dispatch.ops import \
            _combine as kernel_combine
        return kernel_combine(y, plan.dst, plan.keep.astype(jnp.int32),
                              plan.slot, weights, block_t=self.block_t,
                              interpret=self.interpret)


# ----------------------------------------------------------------------
# sharded — regions as shards of a mesh axis (inside shard_map)
# ----------------------------------------------------------------------
@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class CombineRoute:
    """The ``all_to_all`` lane layout of one sharded combine, persisted.

    ``ShardedBackend.combine`` routes *addresses* before it routes rows:
    each source scatters the slab rows its packets occupy into
    per-destination-shard lanes and one ``all_to_all`` delivers them.  That
    address half depends only on the plan (which depends only on the
    offered packets and the register epoch) — so steady-state decode ticks
    can build it once per reconfiguration (``build_route``) and replay it
    (``combine(..., route=...)``), paying ICI setup per epoch instead of
    per token.  Replaying a route built for a different plan/slab shape is
    a correctness bug on the caller.
    """

    addr_recv: jax.Array   # [n_src, W] int32 — my slab rows to serve, per
    #                        requesting source shard (-1 = empty lane row)
    keep: jax.Array        # [T] bool — granted and within this slab depth
    pos: jax.Array         # [T] int32 — packet's lane position in its group
    dshard: jax.Array      # [T] int32 — destination shard per packet


def _axis_size(axis_name: str) -> int:
    fn = getattr(jax.lax, "axis_size", None)
    if fn is not None:
        return fn(axis_name)
    return jax.lax.psum(1, axis_name)


# ----------------------------------------------------------------------
# sharded data movement with custom VJPs
#
# ``all_to_all(split_axis=0, concat_axis=0)`` is a self-inverse block
# permutation, so the transpose of (scatter -> all_to_all -> sum) is
# (broadcast -> the same all_to_all -> gather at the same flat address):
# the backward pass rides the identical ICI route the forward memoized —
# O(packets · D) bytes, no dense routing matrix, no slab all-gather.
# ----------------------------------------------------------------------
@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _sharded_dispatch_at(axis_name, geom, x, addr):
    """Scatter local packets into the send slab at flat ``dst*C+slot``
    addresses, ``all_to_all`` the per-shard blocks, and sum per-source
    contributions into this shard's receive slabs [pps, C, D].
    ``geom = (n_src, pps, capacity)`` — static, resolved outside.
    Backward oracle: :func:`sharded_dispatch_at_bwd_ref`."""
    n_src, pps, capacity = geom
    n_dst = n_src * pps
    D = x.shape[-1]
    send = jnp.zeros((n_dst * capacity + 1, D),
                     x.dtype).at[addr].add(x)  # fablint: trash-row
    send = send[:n_dst * capacity].reshape(n_src, pps, capacity, D)
    recv = jax.lax.all_to_all(send, axis_name, split_axis=0,
                              concat_axis=0, tiled=False)
    return jnp.sum(recv, axis=0)                             # [pps, C, D]


def _sharded_dispatch_at_fwd(axis_name, geom, x, addr):
    return _sharded_dispatch_at(axis_name, geom, x, addr), addr


def _sharded_dispatch_at_bwd(axis_name, geom, addr, g):
    n_src, pps, capacity = geom
    n_dst = n_src * pps
    D = g.shape[-1]
    # The forward's sum over sources broadcasts; the self-inverse
    # all_to_all carries every destination shard's cotangent block home.
    gb = jnp.broadcast_to(g[None], (n_src, pps, capacity, D))
    back = jax.lax.all_to_all(gb, axis_name, split_axis=0,
                              concat_axis=0, tiled=False)
    flat = jnp.concatenate(
        [back.reshape(n_dst * capacity, D), jnp.zeros((1, D), g.dtype)],
        axis=0)
    return jnp.take(flat, addr, axis=0, mode="clip"), None


_sharded_dispatch_at.defvjp(_sharded_dispatch_at_fwd,
                            _sharded_dispatch_at_bwd)


def sharded_dispatch_at_bwd_ref(axis_name, geom, g, addr):
    """Dense one-hot oracle for the :func:`_sharded_dispatch_at` backward
    (explicit [T, n_dst*C+1] routing matrix — test-only; must still run
    inside the same ``shard_map``)."""
    n_src, pps, capacity = geom
    n_dst = n_src * pps
    D = g.shape[-1]
    gb = jnp.broadcast_to(g[None], (n_src, pps, capacity, D))
    back = jax.lax.all_to_all(gb, axis_name, split_axis=0,
                              concat_axis=0, tiled=False)
    flat = jnp.concatenate(
        [back.reshape(n_dst * capacity, D), jnp.zeros((1, D), g.dtype)],
        axis=0)
    oh = (addr[:, None]
          == jnp.arange(n_dst * capacity + 1)[None, :]).astype(g.dtype)
    return jnp.einsum("tr,rd->td", oh, flat)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _sharded_combine_at(axis_name, n_src, y, addr_recv, idx, gate,
                        weights):
    """Address-routed sharded combine over a prebuilt route: gather my
    slab rows per requesting shard (``addr_recv``; -1 = empty lane),
    ``all_to_all`` them home, and read each packet's lane at ``idx =
    dshard * W + min(pos, W-1)`` gated by ``gate`` (the route's ``keep``).
    Backward oracle: :func:`sharded_combine_at_bwd_ref`."""
    pps, C, D = y.shape
    W = addr_recv.shape[-1]
    rows = jnp.take(y.reshape(pps * C, D), addr_recv, axis=0,
                    mode="clip")
    rows = rows * (addr_recv >= 0).astype(y.dtype)[..., None]
    back = jax.lax.all_to_all(rows, axis_name, split_axis=0,
                              concat_axis=0, tiled=False)
    flat = back.reshape(n_src * W, D)
    out = jnp.take(flat, idx, axis=0, mode="clip")
    return out * (gate.astype(y.dtype) * weights)[:, None]


def _sharded_combine_at_fwd(axis_name, n_src, y, addr_recv, idx, gate,
                            weights):
    pps, C, D = y.shape
    W = addr_recv.shape[-1]
    rows = jnp.take(y.reshape(pps * C, D), addr_recv, axis=0,
                    mode="clip")
    rows = rows * (addr_recv >= 0).astype(y.dtype)[..., None]
    back = jax.lax.all_to_all(rows, axis_name, split_axis=0,
                              concat_axis=0, tiled=False)
    flat = back.reshape(n_src * W, D)
    pre = jnp.take(flat, idx, axis=0, mode="clip")
    out = pre * (gate.astype(y.dtype) * weights)[:, None]
    return out, (y, pre, addr_recv, idx, gate, weights)


def _sharded_combine_at_bwd(axis_name, n_src, res, g):
    y, pre, addr_recv, idx, gate, weights = res
    pps, C, _ = y.shape
    y_dtype = y.dtype
    W = addr_recv.shape[-1]
    D = g.shape[-1]
    gw = g * (gate.astype(g.dtype) * weights.astype(g.dtype))[:, None]
    # Scatter each packet's weighted cotangent into its lane (dropped
    # packets carry exact zeros and park in the trash lane row), ride the
    # self-inverse all_to_all back to the owning shard, and scatter-add
    # into its slab at the same served addresses.
    lane = jnp.where(gate, idx, jnp.int32(n_src * W))
    d_flat = jnp.zeros((n_src * W + 1, D), y_dtype).at[lane].add(
        gw.astype(y_dtype))  # fablint: trash-row
    d_back = d_flat[:n_src * W].reshape(n_src, W, D)
    d_rows = jax.lax.all_to_all(d_back, axis_name, split_axis=0,
                                concat_axis=0, tiled=False)
    live = addr_recv >= 0
    d_rows = d_rows * live.astype(y_dtype)[..., None]
    raddr = jnp.where(live, addr_recv, jnp.int32(pps * C))
    d_y = jnp.zeros((pps * C + 1, D), y_dtype).at[
        raddr.reshape(-1)].add(
        d_rows.reshape(-1, D))  # fablint: trash-row
    d_y = d_y[:pps * C].reshape(pps, C, D)
    d_w = (jnp.sum(g * pre.astype(g.dtype), axis=-1)
           * gate.astype(g.dtype)).astype(weights.dtype)
    return d_y, None, None, None, d_w


_sharded_combine_at.defvjp(_sharded_combine_at_fwd,
                           _sharded_combine_at_bwd)


def sharded_combine_at_bwd_ref(axis_name, n_src, g, y, addr_recv, idx,
                               gate, weights):
    """Dense one-hot oracle for the :func:`_sharded_combine_at` backward
    ((d_y, d_weights) via explicit routing matrices — test-only; must run
    inside the same ``shard_map``)."""
    pps, C, D = y.shape
    W = addr_recv.shape[-1]
    gf = g.astype(jnp.float32)
    gw = gf * (gate.astype(jnp.float32) * weights.astype(jnp.float32))[:, None]
    oh_lane = ((idx[:, None] == jnp.arange(n_src * W)[None, :])
               & gate[:, None]).astype(jnp.float32)
    d_back = jnp.einsum("tl,td->ld", oh_lane, gw).reshape(n_src, W, D)
    d_rows = jax.lax.all_to_all(d_back, axis_name, split_axis=0,
                                concat_axis=0, tiled=False)
    oh_recv = ((addr_recv[..., None] == jnp.arange(pps * C)[None, None, :])
               & (addr_recv >= 0)[..., None]).astype(jnp.float32)
    d_y = jnp.einsum("swr,swd->rd", oh_recv, d_rows).reshape(pps, C, D)
    rows = jnp.einsum("swr,rd->swd", oh_recv,
                      y.reshape(pps * C, D).astype(jnp.float32))
    back = jax.lax.all_to_all(rows, axis_name, split_axis=0,
                              concat_axis=0, tiled=False)
    pre = jnp.einsum("tl,ld->td", oh_lane,
                     back.reshape(n_src * W, D))
    d_w = jnp.sum(gf * pre, axis=-1)
    return d_y.astype(y.dtype), d_w.astype(weights.dtype)


class ShardedBackend:
    """Crossbar over ICI collectives: every method must be called inside a
    ``shard_map`` over ``axis_name``; each shard is one source region (its
    source id is the axis index — the ``src`` argument is ignored) and
    holds its local packets.  The register file's ``n_ports`` destinations
    partition contiguously across the axis (``ports_per_shard = n_ports //
    axis_size`` slave ports per shard — 1 in the region-per-shard case, an
    expert block in MoE expert parallelism); after ``dispatch`` each shard
    owns the receive slabs of its own port block.  ``counts``/``drops``
    are psummed so every shard sees the oracle's global histogram."""

    name = "sharded"
    #: slabs are partitioned across the axis; the fabric's single-device
    #: address cache does not describe this data plane.
    uses_shared_scatter = False

    def __init__(self, axis_name: str):
        self.axis_name = axis_name

    def effective_src(self, src: jax.Array) -> jax.Array:
        """The source port this backend actually plans with — its mesh
        axis index, not the caller's ``src`` vector (which it ignores).
        The checkify sanitizer asks for this so its isolation re-check
        matches the plan's own arbitration inputs."""
        return jnp.full_like(src.astype(jnp.int32),
                             jax.lax.axis_index(self.axis_name))

    def ports_per_shard(self, regs: CrossbarRegisters) -> int:
        """Slave ports each shard owns; ``n_ports`` must divide evenly."""
        n_src = _axis_size(self.axis_name)
        n_dst = regs.n_ports
        if n_dst % n_src:
            raise ValueError(
                f"sharded backend needs n_ports ({n_dst}) divisible by the "
                f"'{self.axis_name}' axis size ({n_src}) so the port space "
                f"partitions into equal per-shard blocks")
        return n_dst // n_src

    def plan(self, dst: jax.Array, src: jax.Array,
             regs: CrossbarRegisters) -> DispatchPlan:
        ax = self.axis_name
        n_dst = regs.n_ports
        self.ports_per_shard(regs)                           # divisibility
        me = jax.lax.axis_index(ax)
        dst = dst.astype(jnp.int32)
        in_range = (dst >= 0) & (dst < n_dst)
        dstc = jnp.clip(dst, 0, n_dst - 1)
        iso_ok = (in_range & regs.allowed[me, dstc]
                  & ~regs.reset[me] & ~regs.reset[dstc])
        rank = arbiter._stream_ranks(dstc, iso_ok, n_dst)
        quota = regs.quota[dstc, me]
        keep_pre = iso_ok & ((quota == 0) | (rank < quota))

        # Global WRR slots from the all-gathered per-source granted counts.
        mine = jnp.zeros((n_dst,), jnp.int32).at[dstc].add(
            keep_pre.astype(jnp.int32), mode="drop")
        granted = jax.lax.all_gather(mine, ax)               # [src, dst]
        slot = wrr_slots(rank, granted, dstc, me)
        cap_ok = slot < regs.capacity[dstc]
        keep = keep_pre & cap_ok
        error = jnp.where(
            ~iso_ok, jnp.int32(ErrorCode.INVALID_DEST),
            jnp.where(~keep_pre, jnp.int32(ErrorCode.GRANT_TIMEOUT),
                      jnp.where(cap_ok, jnp.int32(ErrorCode.OK),
                                jnp.int32(ErrorCode.ACK_TIMEOUT))))
        counts = jax.lax.psum(
            jnp.zeros((n_dst,), jnp.int32).at[dstc].add(
                keep.astype(jnp.int32), mode="drop"),
            ax)
        drops = jax.lax.psum(
            jnp.zeros((4,), jnp.int32).at[error].add(1, mode="drop"), ax)
        return DispatchPlan(keep=keep, slot=jnp.where(keep, slot, 0),
                            dst=dst, error=error, counts=counts, drops=drops)

    def dispatch(self, x: jax.Array, plan: DispatchPlan,
                 regs: CrossbarRegisters, capacity: int) -> jax.Array:
        """Local packets [T_loc, D] -> this shard's receive slabs [P, C, D]
        (``P = ports_per_shard`` — the shard's contiguous slave-port block).

        The send slab is scatter-built at the shared flat ``dst * C +
        slot`` address (no [T, n_dst, C] selection tensor); slots are
        globally unique per destination, so the per-source contributions
        coming out of the ``all_to_all`` just sum."""
        n_src = _axis_size(self.axis_name)
        n_dst = regs.n_ports
        pps = self.ports_per_shard(regs)
        addr = arbiter.flat_slot_addr(plan, n_dst, capacity)
        # The custom-VJP primitive replays the same flat address route in
        # the backward pass (gather after the self-inverse all_to_all).
        return _sharded_dispatch_at(self.axis_name, (n_src, pps, capacity),
                                    x, addr)                 # [P, C, D]

    def build_route(self, plan: DispatchPlan,
                    capacity: int) -> CombineRoute:
        """The address half of :meth:`combine`: one ``all_to_all`` of int
        addresses that tells every shard which of its slab rows each
        source's packets occupy.  Depends only on the plan and the slab
        depth — persist it across ticks within a register epoch and replay
        via ``combine(..., route=...)`` (a shell event that bumps the epoch
        changes the plan, so the route must be rebuilt with it)."""
        ax = self.axis_name
        n_src = _axis_size(ax)
        n_dst = plan.counts.shape[0]
        pps = n_dst // n_src
        C = capacity
        T = plan.dst.shape[0]
        # Row budget per (source, destination-shard) lane: a source cannot
        # land more packets on one shard than it has packets, nor more than
        # the shard's port block holds.
        W = min(T, pps * C)
        dstc = jnp.clip(plan.dst, 0, n_dst - 1)
        dshard = dstc // pps
        # Over-slab slots drop like everywhere else on the scatter data
        # plane (the dispatch trashed them via ``flat_slot_addr``); without
        # this guard the clip in ``combine`` would alias them onto the
        # last row.
        keep = plan.keep & (plan.slot < C)
        # Position of each kept packet within its destination-shard group.
        pos = arbiter._stream_ranks(dshard, keep, n_src)
        local_addr = (dstc % pps) * C + plan.slot            # row in dest's y
        # Scatter addresses into the per-destination-shard send lanes
        # (lane W is the trash slot for drops; -1 marks empty rows).
        lane = dshard * (W + 1) + jnp.where(keep, jnp.minimum(pos, W), W)
        addr_send = jnp.full((n_src * (W + 1),), -1, jnp.int32).at[lane].set(
            jnp.where(keep, local_addr, -1))  # fablint: trash-row (lane W)
        addr_send = addr_send.reshape(n_src, W + 1)[:, :W]
        addr_recv = jax.lax.all_to_all(addr_send, ax, split_axis=0,
                                       concat_axis=0, tiled=False)
        return CombineRoute(addr_recv=addr_recv, keep=keep, pos=pos,
                            dshard=dshard)

    def combine(self, y: jax.Array, plan: DispatchPlan,
                weights: jax.Array, *,
                route: Optional[CombineRoute] = None) -> jax.Array:
        """Local result slabs [P, C, D] -> local packets [T_loc, D], weighted.

        Address-route gather: each source shard sends, per destination
        shard, the local slab rows its packets occupy (one ``all_to_all``
        of int addresses), the destination gathers those rows out of its
        own [P, C, D] block, and a second ``all_to_all`` carries them
        home.  Bytes on the interconnect are O(packets · D) — the
        all-gather of *entire* result slabs this replaces shipped the full
        [n_src, P, C, D] capacity surface to every shard, even though each
        source only reads its own packets' rows.  Dropped packets get
        zeros.

        ``route`` replays a persisted :class:`CombineRoute` (built by
        :meth:`build_route` for THIS plan and this slab depth), skipping
        the address ``all_to_all`` — the steady-state mode where ICI
        setup is paid once per reconfiguration, not per token.  Results
        are bit-identical with and without a route."""
        ax = self.axis_name
        n_src = _axis_size(ax)
        pps, C, D = y.shape
        T = plan.dst.shape[0]
        if T == 0 or C == 0:        # nothing sent / nothing grantable
            return jnp.zeros((T, D), y.dtype)
        if route is None:
            route = self.build_route(plan, C)
        W = route.addr_recv.shape[-1]
        # In-range by construction (dshard < n_src, min(pos, W-1) < W);
        # dropped packets read a garbage row that ``keep`` zeros.  The
        # custom-VJP primitive replays the identical lane route backward.
        idx = route.dshard * W + jnp.minimum(route.pos, W - 1)
        return _sharded_combine_at(ax, n_src, y, route.addr_recv, idx,
                                   route.keep, weights)


# ----------------------------------------------------------------------
# registry
# ----------------------------------------------------------------------
_BACKENDS: Dict[str, Callable[..., object]] = {
    "reference": ReferenceBackend,
    "pallas": PallasBackend,
    "sharded": ShardedBackend,
}


def register_fabric_backend(name: str, factory: Callable[..., object],
                            ) -> None:
    """Register a custom backend factory under ``name`` (duck-typed:
    ``plan``/``dispatch``/``combine`` with the signatures above).

    Once registered, the name works everywhere a backend is selected —
    ``Fabric(regs, backend=name)``, ``shell.fabric(backend=name)``, and
    ``moe_apply(dispatch_impl=name)``:

    >>> from repro.fabric import (Fabric, ReferenceBackend, get_backend,
    ...                           register_fabric_backend)
    >>> class LoggingBackend(ReferenceBackend):
    ...     name = "logging"
    >>> register_fabric_backend("logging", LoggingBackend)
    >>> get_backend("logging").name
    'logging'
    """
    _BACKENDS[name] = factory


def get_backend(spec, **kwargs):
    """Resolve a backend: an instance passes through, a name constructs."""
    if not isinstance(spec, str):
        return spec
    try:
        factory = _BACKENDS[spec]
    except KeyError:
        raise ValueError(f"unknown fabric backend {spec!r}; "
                         f"registered: {sorted(_BACKENDS)}") from None
    return factory(**kwargs)


def backend_names():
    return sorted(_BACKENDS)
