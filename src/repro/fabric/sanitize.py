"""Runtime invariant checks for the fabric data plane (checkify).

The static half of this layer is ``tools/fablint`` (rules FAB001-FAB005);
this is the dynamic half: ``jax.experimental.checkify`` assertions threaded
through plan/dispatch/combine when a fabric is constructed with
``debug="sanitize"|"strict"|True`` or under ``REPRO_FABRIC_DEBUG=1``.

Two levels:

- ``"sanitize"`` — structural invariants that hold on every correct plan,
  whatever the traffic: granted packets carry in-range destinations and
  slots under the *gated* capacity, per-port grant counts never exceed the
  gated capacity, granted packets respect the isolation/reset register
  masks, and no NaN enters a receive slab.  These only fire on a data-plane
  bug (or NaN traffic) — never on hostile traffic, which the fabric's job
  is to mask.
- ``"strict"`` — sanitize plus *fault surfacing*: traffic that the masked
  path would silently drop raises instead.  A packet with a real (not
  ``dst = -1`` padding) out-of-range or isolation-blocked destination, or
  an over-capacity burst (ACK_TIMEOUT), becomes a
  ``checkify.JaxRuntimeError``.  Quota drops (GRANT_TIMEOUT) stay silent
  at both levels — WRR quota cuts are policy, not faults.

All checks compile to nothing when debug is off — the normal path never
imports checkify into its jaxpr (``benchmarks/fabric_bench.py`` pins the
zero-overhead claim).  See ``docs/invariants.md``.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import checkify

from repro.core.arbiter import DispatchPlan
from repro.core.registers import CrossbarRegisters, ErrorCode

LEVELS = ("sanitize", "strict")


def check_plan(plan: DispatchPlan, regs: CrossbarRegisters,
               src: Optional[jax.Array], backend, level: str) -> None:
    """Assert plan invariants against the *gated* register file.

    ``src`` is the caller's source-port vector; backends that derive the
    effective source themselves (the sharded backend uses its mesh axis
    index) expose ``effective_src`` and override it.
    """
    n = regs.n_ports
    keep = plan.keep
    dst = plan.dst
    ok_range = ~keep | ((dst >= 0) & (dst < n))
    checkify.check(jnp.all(ok_range),
                   "fabric sanitizer: granted packet with out-of-range "
                   "destination (n_ports={n})", n=jnp.int32(n))

    dstc = jnp.clip(dst, 0, n - 1)
    cap = regs.capacity[dstc]
    ok_slot = ~keep | ((plan.slot >= 0) & (plan.slot < cap))
    checkify.check(jnp.all(ok_slot),
                   "fabric sanitizer: granted slot outside the gated "
                   "capacity of its destination port")

    checkify.check(jnp.all(plan.counts <= regs.capacity),
                   "fabric sanitizer: per-port grant count exceeds the "
                   "gated capacity (counts={counts})", counts=plan.counts)

    eff = getattr(backend, "effective_src", None)
    src_eff = src if eff is None else eff(src if src is not None else dst)
    if src_eff is not None:
        srcc = jnp.clip(src_eff.astype(jnp.int32), 0, n - 1)
        allowed = (regs.allowed[srcc, dstc]
                   & ~regs.reset[srcc] & ~regs.reset[dstc])
        checkify.check(jnp.all(~keep | allowed),
                       "fabric sanitizer: granted packet violates the "
                       "isolation/reset register mask of its (src, dst) "
                       "pair")

    if level == "strict":
        real = dst != -1            # -1 is the sanctioned padding sentinel
        invalid = real & (plan.error == jnp.int32(ErrorCode.INVALID_DEST))
        checkify.check(~jnp.any(invalid),
                       "fabric strict: packet sprayed at an invalid "
                       "destination (out of range or isolation-masked); "
                       "the masked path would drop it silently")
        acked_out = plan.error == jnp.int32(ErrorCode.ACK_TIMEOUT)
        checkify.check(~jnp.any(acked_out),
                       "fabric strict: over-capacity burst — packets "
                       "dropped with ACK_TIMEOUT "
                       "(drops={drops})", drops=plan.drops)


def check_slabs(slabs: jax.Array, level: str) -> None:
    """No NaN may enter a receive slab (it would propagate through the
    module and combine into packets that were never at fault)."""
    del level                       # checked at both levels
    if jnp.issubdtype(slabs.dtype, jnp.floating):
        checkify.check(~jnp.any(jnp.isnan(slabs)),
                       "fabric sanitizer: NaN entered a receive slab")


def check_combine(plan: DispatchPlan, slab_capacity: int,
                  level: str) -> None:
    """Every granted packet must address a slot that exists in the slab
    actually handed to combine (a smaller slab is legal only for packets
    the plan already dropped)."""
    del level
    ok = ~plan.keep | (plan.slot < slab_capacity)
    checkify.check(jnp.all(ok),
                   "fabric sanitizer: granted slot beyond the combine "
                   "slab's capacity ({c})", c=jnp.int32(slab_capacity))
