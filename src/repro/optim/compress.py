"""Error-feedback int8 gradient compression for the cross-pod (DP) axis.

At 2 pods the pod-axis gradient reduce crosses the slowest links in the
system (data-centre network / inter-pod ICI), so gradients are compressed to
int8 with per-tensor scales before the cross-pod all-reduce and the
quantisation error is carried forward (error feedback keeps SGD/Adam unbiased
to first order — Seide et al. 2014; Karimireddy et al. 2019).

Usage (inside the train step, pod axis only):

    g_q, scale, err = compress_int8(g + err_prev)
    g_sum = jax.lax.psum(g_q.astype(f32) * scale, "pod")  # 4x fewer bytes
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def compress_int8(g: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (int8 values, f32 scale, residual error)."""
    gf = g.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    err = gf - q.astype(jnp.float32) * scale
    return q, scale, err


def decompress_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def error_feedback_update(grads, errors):
    """Fold the previous round's quantisation error into this round's grads."""
    if errors is None:
        return grads
    return jax.tree.map(lambda g, e: g + e.astype(g.dtype), grads, errors)
