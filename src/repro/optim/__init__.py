from repro.optim.adamw import AdamW, OptState, cosine_schedule
from repro.optim.compress import (compress_int8, decompress_int8,
                                  error_feedback_update)

__all__ = ["AdamW", "OptState", "cosine_schedule",
           "compress_int8", "decompress_int8", "error_feedback_update"]
