"""AdamW with sharded state (optimizer state inherits parameter sharding —
since parameters are FSDP-sharded over the data axis, this is ZeRO-style
state partitioning by construction), plus a cosine LR schedule.

No optax dependency: the container guarantees only jax/numpy/pytest, and the
update rule is 20 lines.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    step: jax.Array
    m: Any              # first moment  (f32, param-shaped)
    v: Any              # second moment (f32, param-shaped)


def cosine_schedule(base_lr: float, warmup: int, total: int):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / max(1, warmup)
        t = jnp.clip((step - warmup) / max(1, total - warmup), 0.0, 1.0)
        cos = 0.5 * base_lr * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup, warm, cos)
    return lr


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Any = 1e-3                 # float or callable(step) -> lr
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0

    def init(self, params) -> OptState:
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return OptState(step=jnp.zeros((), jnp.int32),
                        m=jax.tree.map(zeros, params),
                        v=jax.tree.map(zeros, params))

    def update(self, grads, state: OptState, params) -> Tuple[Any, OptState]:
        step = state.step + 1
        # Global-norm clip (f32).
        gsq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                  for g in jax.tree.leaves(grads))
        gnorm = jnp.sqrt(gsq)
        scale = jnp.minimum(1.0, self.grad_clip / jnp.maximum(gnorm, 1e-12))

        lr = self.lr(step) if callable(self.lr) else self.lr
        bc1 = 1 - self.b1 ** step.astype(jnp.float32)
        bc2 = 1 - self.b2 ** step.astype(jnp.float32)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32) * scale
            m2 = self.b1 * m + (1 - self.b1) * g
            v2 = self.b2 * v + (1 - self.b2) * g * g
            mh = m2 / bc1
            vh = v2 / bc2
            delta = mh / (jnp.sqrt(vh) + self.eps) \
                + self.weight_decay * p.astype(jnp.float32)
            return (-lr * delta).astype(p.dtype), m2, v2

        out = jax.tree.map(upd, grads, state.m, state.v, params)
        updates = jax.tree.map(lambda t: t[0], out,
                               is_leaf=lambda x: isinstance(x, tuple))
        m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
        v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
        return updates, OptState(step=step, m=m, v=v)

    @staticmethod
    def apply_updates(params, updates):
        return jax.tree.map(lambda p, u: p + u.astype(p.dtype),
                            params, updates)
