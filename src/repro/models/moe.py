"""Mixture-of-Experts layer routed through the paper's crossbar mechanism.

The mapping is exact, not an analogy:

- *sources* are token groups (the data-parallel regions a batch shard came
  from — the crossbar's master ports),
- *destinations* are experts (slave ports),
- the *WRR package quota* per (source, destination) pair is the per-group
  expert capacity ``C`` — bandwidth allocation in packages (§IV-E.1),
- *isolation masks* restrict which experts a tenant's tokens may reach
  (§IV-E.2), enforced inside the dispatch exactly like the one-hot-AND,
- over-quota packets are dropped with the paper's error codes and surface in
  the router's drop statistics (the register file's status read-back).

Grouped dense formulation (Switch/Mesh-TF style): groups keep the dispatch
tensor O(G * Tg^2) instead of O(T^2); each group independently enforces the
pairwise quota — which is precisely ``pairwise_dispatch_plan`` vmapped over
groups. Group size is a tunable (perf hillclimb lever).

Mesh expert parallelism (``dispatch_impl="sharded"``): experts become slave
ports *partitioned across a mesh axis* and tokens cross the axis through
``repro.fabric.ShardedBackend``'s global-WRR ``all_to_all`` — one crossbar
over the whole mesh instead of local per-group fabrics.  The register file
is a traced argument end to end, so a live ``Shell`` reconfigures routing
between jitted steps with zero retraces (see ``moe_apply_sharded`` /
``moe_forward_sharded`` and ``tests/test_moe_sharded.py``).
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ParamDef
from repro.models.config import MoEConfig


def moe_defs(d_model: int, d_ff: int, moe: MoEConfig, act: str) -> Dict[str, ParamDef]:
    f_in = 2 * d_ff if act in ("swiglu", "geglu") else d_ff
    return {
        "w_router": ParamDef((d_model, moe.n_experts), ("fsdp", None)),
        "w_in": ParamDef((moe.n_experts, d_model, f_in), (None, "fsdp", "tp")),
        "w_out": ParamDef((moe.n_experts, d_ff, d_model), (None, "tp", "fsdp")),
    }


def expert_capacity(group_tokens: int, moe: MoEConfig, multiple: int = 8) -> int:
    c = math.ceil(moe.capacity_factor * group_tokens * moe.top_k / moe.n_experts)
    return max(multiple, math.ceil(c / multiple) * multiple)


def moe_apply(params, x: jax.Array, moe: MoEConfig, act: str, *,
              group_size: int = 1024,
              expert_mask: Optional[jax.Array] = None,
              dispatch_impl: str = "dense",
              registers=None, axis_name: str = "expert",
              capacity: Optional[int] = None,
              kernel_mode: Optional[str] = None
              ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """x: [B, S, d] -> (y [B, S, d], stats).

    ``expert_mask``: optional [E] bool — the tenant's allowed-destinations
    register; disallowed experts receive no traffic and their packets are
    dropped (INVALID_DEST analogue), surfacing in ``stats['iso_dropped']``.

    ``dispatch_impl``: "dense" is the Mesh-TF one-hot matmul formulation
    (the faithful baseline — the crossbar's selection matrix realised on
    the MXU). Its dispatch/combine einsums cost 2*T*k*E*C*d FLOPs and an
    O(T*E*C) selection tensor — ~60x the expert matmuls at pod scale.
    "gather" routes by indexed scatter/gather instead: O(T*k*d) data
    movement and no selection tensor (§Perf iteration "moe-gather").
    Identical packet semantics: same ranks, same WRR quota drops.

    "sharded" is mesh expert parallelism: it must run *inside a shard_map*
    over ``axis_name`` (experts are slave ports partitioned across the
    axis, tokens cross it via the global-WRR ``all_to_all``) and routes
    through :func:`moe_apply_sharded` — ``registers``/``capacity`` pass
    through, ``group_size`` is ignored (the shard is the group).

    Any other value names a ``repro.fabric`` backend ("reference",
    "pallas", or a registered custom): the layer then routes every group
    through a ``Fabric.transfer`` round-trip — experts are crossbar slave
    ports, ``expert_mask`` is the isolation row, capacity is the slab
    depth — so the MoE data plane and the shell's interconnect share one
    implementation (and one plan semantics) instead of re-deriving ranks
    here.

    ``kernel_mode`` (``repro.fabric.KernelMode`` or its string aliases)
    selects the fabric's kernel *lowering* on the fabric-backed impls —
    resolved once when the geometry's fabric is first built, never inside
    the traced call (docs/training.md).  The dense/gather impls have no
    kernels and ignore it.
    """
    if dispatch_impl == "gather":
        return moe_apply_gather(params, x, moe, act, group_size=group_size,
                                expert_mask=expert_mask)
    if dispatch_impl == "sharded":
        return moe_apply_sharded(params, x, moe, act, registers=registers,
                                 axis_name=axis_name,
                                 expert_mask=expert_mask, capacity=capacity,
                                 kernel_mode=kernel_mode)
    if dispatch_impl != "dense":
        return moe_apply_fabric(params, x, moe, act, group_size=group_size,
                                expert_mask=expert_mask,
                                backend=dispatch_impl,
                                kernel_mode=kernel_mode)
    B, S, d = x.shape
    E, k = moe.n_experts, moe.top_k
    T = B * S
    g = min(group_size, T)
    G = T // g
    assert G * g == T, f"tokens {T} not divisible by group size {g}"
    xf = x.reshape(G, g, d)

    logits = jnp.einsum("gtd,de->gte", xf, params["w_router"]).astype(jnp.float32)
    if expert_mask is not None:
        logits = jnp.where(expert_mask[None, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)                    # [G, g, E]
    top_p, top_e = jax.lax.top_k(probs, k)                     # [G, g, k]
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # --- crossbar dispatch plan: per-(group, expert) package ranks -------
    dst = top_e.reshape(G, g * k)                              # packets
    w = top_p.reshape(G, g * k).astype(x.dtype)
    cap = expert_capacity(g, moe)
    e_oh = jax.nn.one_hot(dst, E, dtype=jnp.int32)             # [G, gk, E]
    rank = jnp.cumsum(e_oh, axis=1) - e_oh
    rank = jnp.take_along_axis(rank, dst[..., None], axis=2,
                               mode="clip")[..., 0]
    keep = rank < cap                                          # WRR quota
    if expert_mask is not None:
        iso_ok = expert_mask[dst]
        keep &= iso_ok
        iso_dropped = jnp.sum(~iso_ok)
    else:
        iso_dropped = jnp.zeros((), jnp.int32)
    slot = jnp.where(keep, rank, 0)

    sel = (jax.nn.one_hot(dst, E, dtype=x.dtype)
           * keep[..., None].astype(x.dtype))                  # [G, gk, E]
    slot_oh = jax.nn.one_hot(slot, cap, dtype=x.dtype)         # [G, gk, C]
    disp = sel[..., :, None] * slot_oh[..., None, :]           # [G, gk, E, C]

    xk = jnp.repeat(xf, k, axis=1)                             # [G, gk, d]
    xe = jnp.einsum("gtec,gtd->gecd", disp, xk)                # [G, E, C, d]

    h = jnp.einsum("gecd,edf->gecf", xe, params["w_in"])
    if act in ("swiglu", "geglu"):
        gate, up = jnp.split(h, 2, axis=-1)
        a = jax.nn.silu(gate.astype(jnp.float32)) if act == "swiglu" \
            else jax.nn.gelu(gate.astype(jnp.float32))
        h = (a * up.astype(jnp.float32)).astype(x.dtype)
    else:
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    ye = jnp.einsum("gecf,efd->gecd", h, params["w_out"])      # [G, E, C, d]

    comb = disp * w[..., None, None]
    y = jnp.einsum("gtec,gecd->gtd", comb, ye)                 # [G, gk, d]
    y = y.reshape(G, g, k, d).sum(axis=2).reshape(B, S, d)

    # --- router statistics (load-balance aux loss + drop read-back) ------
    frac_tokens = jnp.mean(sel, axis=(0, 1))                   # [E]
    frac_probs = jnp.mean(probs, axis=(0, 1))                  # [E]
    aux_loss = E * jnp.sum(frac_tokens.astype(jnp.float32) * frac_probs)
    stats = {
        "aux_loss": aux_loss,
        "dropped": jnp.sum(~keep),
        "iso_dropped": iso_dropped,
        "capacity": jnp.asarray(cap),
    }
    return y, stats


def moe_apply_gather(params, x: jax.Array, moe: MoEConfig, act: str, *,
                     group_size: int = 1024,
                     expert_mask: Optional[jax.Array] = None
                     ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Gather/scatter MoE dispatch — same grant semantics, no dense
    selection tensor.

    Packet slot assignment is identical to the dense path (rank within the
    (group, expert) stream == the WRR package counter); the slab is filled
    with ``.at[slot].add`` (unique slots, so add == set) and results return
    with ``take_along_axis``. FLOPs: experts only. Bytes: O(T*k*d).
    """
    B, S, d = x.shape
    E, k = moe.n_experts, moe.top_k
    T = B * S
    g = min(group_size, T)
    G = T // g
    assert G * g == T, f"tokens {T} not divisible by group size {g}"
    xf = x.reshape(G, g, d)

    logits = jnp.einsum("gtd,de->gte", xf, params["w_router"]).astype(jnp.float32)
    if expert_mask is not None:
        logits = jnp.where(expert_mask[None, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    dst = top_e.reshape(G, g * k)
    w = top_p.reshape(G, g * k).astype(x.dtype)
    cap = expert_capacity(g, moe)
    e_oh = jax.nn.one_hot(dst, E, dtype=jnp.int32)
    rank = jnp.cumsum(e_oh, axis=1) - e_oh
    rank = jnp.take_along_axis(rank, dst[..., None], axis=2,
                               mode="clip")[..., 0]
    keep = rank < cap
    if expert_mask is not None:
        iso_ok = expert_mask[dst]
        keep &= iso_ok
        iso_dropped = jnp.sum(~iso_ok)
    else:
        iso_dropped = jnp.zeros((), jnp.int32)

    # --- indexed dispatch: packet -> (expert, slot) flat address ---------
    # Dropped packets write to a trash slot (index E*cap) that is sliced off.
    slot_addr = jnp.where(keep, dst * cap + jnp.where(keep, rank, 0),
                          E * cap)                       # [G, gk]
    xk = jnp.repeat(xf, k, axis=1)                       # [G, gk, d]

    def fill(slabs_g, addr_g, xk_g):
        return slabs_g.at[addr_g].add(
            xk_g.astype(slabs_g.dtype))  # fablint: trash-row

    slabs = jnp.zeros((G, E * cap + 1, d), x.dtype)
    slabs = jax.vmap(fill)(slabs, slot_addr, xk)
    xe = slabs[:, :E * cap].reshape(G, E, cap, d)

    h = jnp.einsum("gecd,edf->gecf", xe, params["w_in"])
    if act in ("swiglu", "geglu"):
        gate, up = jnp.split(h, 2, axis=-1)
        a = jax.nn.silu(gate.astype(jnp.float32)) if act == "swiglu" \
            else jax.nn.gelu(gate.astype(jnp.float32))
        h = (a * up.astype(jnp.float32)).astype(x.dtype)
    else:
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    ye = jnp.einsum("gecf,efd->gecd", h, params["w_out"])  # [G, E, cap, d]

    # --- indexed combine: gather each packet's result, weight, sum top-k -
    ye_flat = ye.reshape(G, E * cap, d)
    ye_flat = jnp.concatenate(
        [ye_flat, jnp.zeros((G, 1, d), ye.dtype)], axis=1)  # trash slot
    back = jnp.take_along_axis(ye_flat, slot_addr[..., None], axis=1,
                               mode="clip")
    back = back * (w * keep.astype(w.dtype))[..., None]
    y = back.reshape(G, g, k, d).sum(axis=2).reshape(B, S, d)

    sel_frac = jnp.mean(
        jax.nn.one_hot(dst, E, dtype=jnp.float32)
        * keep[..., None].astype(jnp.float32), axis=(0, 1))
    frac_probs = jnp.mean(probs, axis=(0, 1))
    aux_loss = E * jnp.sum(sel_frac * frac_probs)
    stats = {
        "aux_loss": aux_loss,
        "dropped": jnp.sum(~keep),
        "iso_dropped": iso_dropped,
        "capacity": jnp.asarray(cap),
    }
    return y, stats


def _group_fabric(n_experts: int, capacity: int, backend: str,
                  axis_name: Optional[str] = None,
                  kernel_mode: Optional[str] = None):
    """Normalizing front door for :func:`_group_fabric_cached`: ``"auto"``
    and ``None`` both mean "the platform default" and must share one cache
    key (lm-configured layers say ``"auto"``, direct callers say nothing —
    they should hit the same fabric and the same trace counters)."""
    if kernel_mode == "auto":
        kernel_mode = None
    return _group_fabric_cached(n_experts, capacity, backend, axis_name,
                                kernel_mode)


@functools.lru_cache(maxsize=None)
def _group_fabric_cached(n_experts: int, capacity: int, backend: str,
                         axis_name: Optional[str] = None,
                         kernel_mode: Optional[str] = None):
    """One cached fabric (and its jit caches) per MoE geometry.

    The fabric reads its registers through a mutable cell so the caller
    can swap in the tenant's isolation mask per forward pass — values
    steer routing, the compiled dispatch/combine programs are reused
    across calls (and across layers sharing a geometry).  ``axis_name``
    selects the sharded backend's mesh axis (sharded fabrics are keyed
    per axis so different meshes don't share WRR geometry);
    ``kernel_mode`` is the lowering seam (``repro.fabric.KernelMode``) —
    part of the cache key, so two modes never share compiled programs."""
    from repro.core.registers import CrossbarRegisters
    from repro.fabric import Fabric
    # The cell must hold *concrete* registers even when the cache misses
    # inside a jit/grad trace (e.g. the first call ever is a jitted train
    # step): staged-out register arrays would be cached as dead tracers
    # and poison every later trace with UnexpectedTracerError.
    with jax.ensure_compile_time_eval():
        cell = {"regs": CrossbarRegisters.create(n_experts,
                                                 capacity=capacity)}
    kw = {"axis_name": axis_name} if axis_name is not None else {}
    fabric = Fabric(lambda: cell["regs"], backend=backend,
                    capacity=capacity, kernel_mode=kernel_mode, **kw)
    return fabric, cell


def moe_fabric(n_experts: int, capacity: int, backend: str,
               axis_name: Optional[str] = None,
               kernel_mode: Optional[str] = None):
    """The cached ``Fabric`` a given MoE geometry dispatches through.

    Exposed so tests and telemetry can read ``fabric.trace_count`` (the
    zero-retrace-across-reconfiguration regression pin) or attach
    ``fabric.probe()`` for the layer that serves a geometry."""
    return _group_fabric(n_experts, capacity, backend, axis_name,
                         kernel_mode)[0]


def _moe_router(params, xf: jax.Array, moe: MoEConfig,
                expert_mask: Optional[jax.Array]):
    """Shared router: flat tokens [T, d] -> (dst [T*k], w [T*k], probs).

    ``dst`` is the packet destination stream (expert = slave port id,
    token-major, k packets per token) and ``w`` the renormalized top-k
    combine weights — the single routing semantics every dispatch_impl
    (and the sharded oracle) agrees on."""
    E, k = moe.n_experts, moe.top_k
    logits = jnp.einsum("td,de->te", xf,
                        params["w_router"]).astype(jnp.float32)
    if expert_mask is not None:
        logits = jnp.where(expert_mask[None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)                    # [T, E]
    top_p, top_e = jax.lax.top_k(probs, k)                     # [T, k]
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    dst = top_e.reshape(-1)                                    # [T*k]
    w = top_p.reshape(-1).astype(xf.dtype)
    return dst, w, probs


def _expert_ffn(slabs: jax.Array, w_in: jax.Array, w_out: jax.Array,
                act: str) -> jax.Array:
    """The expert MLP over receive slabs [E?, C, d] (any expert count —
    the sharded path passes each shard's local expert block)."""
    h = jnp.einsum("ecd,edf->ecf", slabs, w_in)
    if act in ("swiglu", "geglu"):
        gate, up = jnp.split(h, 2, axis=-1)
        a = jax.nn.silu(gate.astype(jnp.float32)) if act == "swiglu" \
            else jax.nn.gelu(gate.astype(jnp.float32))
        h = (a * up.astype(jnp.float32)).astype(slabs.dtype)
    else:
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(slabs.dtype)
    return jnp.einsum("ecf,efd->ecd", h, w_out)


def moe_apply_fabric(params, x: jax.Array, moe: MoEConfig, act: str, *,
                     group_size: int = 1024,
                     expert_mask: Optional[jax.Array] = None,
                     backend: str = "reference",
                     kernel_mode: Optional[str] = None
                     ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """MoE dispatch as a ``repro.fabric`` transfer — one data-plane impl.

    Per group: tokens are packets from one master port, experts are the
    slave ports, ``expert_mask`` is the tenant isolation row, and the
    expert capacity is the receive-slab depth.  The whole
    plan/dispatch/expert/combine round-trip is a single vmapped
    ``Fabric.transfer`` with the expert FFN as ``apply_fn`` — so whichever
    backend serves the shell (reference oracle, blockwise Pallas kernels)
    also serves the MoE layer, with the paper's error codes as the drop
    statistics.
    """
    from repro.core.registers import ErrorCode

    B, S, d = x.shape
    E, k = moe.n_experts, moe.top_k
    T = B * S
    g = min(group_size, T)
    G = T // g
    assert G * g == T, f"tokens {T} not divisible by group size {g}"
    xf = x.reshape(G, g, d)

    logits = jnp.einsum("gtd,de->gte", xf, params["w_router"]).astype(jnp.float32)
    if expert_mask is not None:
        logits = jnp.where(expert_mask[None, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    dst = top_e.reshape(G, g * k)
    w = top_p.reshape(G, g * k).astype(x.dtype)
    cap = expert_capacity(g, moe)

    fabric, cell = _group_fabric(E, cap, backend, kernel_mode=kernel_mode)
    canonical = cell["regs"]
    # Fully specify the isolation mask every call — the cell is shared
    # across calls (and tenants) on this geometry, so nothing may inherit
    # a previous call's mask; restored below so no (possibly traced) mask
    # outlives this forward pass.
    allowed = (jnp.broadcast_to(expert_mask[None, :], (E, E))
               if expert_mask is not None
               else jnp.ones((E, E), bool))
    cell["regs"] = dataclasses.replace(canonical, allowed=allowed)
    src = jnp.zeros((g * k,), jnp.int32)

    def experts_fn(slabs):                                 # [E, C, d]
        return _expert_ffn(slabs, params["w_in"], params["w_out"], act)

    def one_group(xg, dg, wg):
        # dispatch/combine are the fabric's shape-cached jits; the expert
        # compute stays in the caller's trace (params close over nothing
        # that would key a recompile).
        xk = jnp.repeat(xg, k, axis=0)                     # [gk, d]
        slabs, plan = fabric.dispatch(xk, dg, src)
        return fabric.combine(experts_fn(slabs), plan, weights=wg), plan

    try:
        y, plans = jax.vmap(one_group)(xf, dst, w)         # y [G, gk, d]
    finally:
        cell["regs"] = canonical
    y = y.reshape(G, g, k, d).sum(axis=2).reshape(B, S, d)

    frac_tokens = (jnp.sum(plans.counts, axis=0) / (G * g * k)
                   ).astype(jnp.float32)
    frac_probs = jnp.mean(probs, axis=(0, 1))
    aux_loss = E * jnp.sum(frac_tokens * frac_probs)
    stats = {
        "aux_loss": aux_loss,
        "dropped": jnp.sum(~plans.keep),
        "iso_dropped": jnp.sum(plans.drops[:, ErrorCode.INVALID_DEST]),
        "capacity": jnp.asarray(cap),
    }
    return y, stats


def moe_apply_sharded(params, x: jax.Array, moe: MoEConfig, act: str, *,
                      registers=None, axis_name: str = "expert",
                      expert_mask: Optional[jax.Array] = None,
                      capacity: Optional[int] = None,
                      kernel_mode: Optional[str] = None
                      ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Mesh expert parallelism through the sharded fabric backend.

    Must run **inside a shard_map** over ``axis_name`` (use
    :func:`moe_forward_sharded` for the wrapper): tokens are sharded
    across the axis (``x`` is this shard's [B_loc, S, d] slice), experts
    are crossbar slave ports partitioned contiguously across it
    (``params['w_in']``/``['w_out']`` are this shard's [E_loc, ...]
    blocks; ``params['w_router']`` is replicated).  Tokens cross the axis
    via the oracle-equivalent global-WRR ``all_to_all``
    (``ShardedBackend``), so the expert-parallel data plane and the
    shell's interconnect are the same implementation.

    ``registers`` is the E-port crossbar register file and stays a
    *traced argument*: pass it through the enclosing jit/shard_map and a
    ``Shell.post(Grow/Shrink/FailRegion)`` re-routes the next step with
    zero retraces (``moe_fabric(E, cap, "sharded", axis).trace_count`` is
    the regression pin).  Defaults to a fully-open file when omitted.

    Extra stats over the local paths: ``offered_packets`` /
    ``granted_packets`` (global, ``dropped = offered - granted``),
    ``counts`` (global per-expert grant histogram) and
    ``remote_packets`` / ``local_packets`` — packets that crossed the
    mesh axis vs. stayed on their source shard (the §IV-E crossbar hops
    that cost ICI bandwidth) — plus their per-*port* splits
    ``remote_counts`` / ``local_counts`` ([E] vectors), so the manager can
    rank individual ports (and the Migrate moves that would relocate
    them) by ICI savings.  ``Fabric.account_stats`` folds all of them
    into manager telemetry.
    """
    from repro.core.registers import CrossbarRegisters, ErrorCode

    E, k = moe.n_experts, moe.top_k
    B_loc, S, d = x.shape
    T_loc = B_loc * S
    E_loc = params["w_in"].shape[0]
    if E_loc == 0 or E % E_loc:
        raise ValueError(
            f"local expert block ({E_loc}) must divide n_experts ({E}); "
            f"shard w_in/w_out over the '{axis_name}' mesh axis")
    n_shards = E // E_loc
    cap = (capacity if capacity is not None
           else expert_capacity(T_loc * n_shards, moe))
    if registers is None:
        registers = CrossbarRegisters.create(E, capacity=cap)
    xf = x.reshape(T_loc, d)
    dst, w, probs = _moe_router(params, xf, moe, expert_mask)

    fabric, _ = _group_fabric(E, cap, "sharded", axis_name, kernel_mode)
    xk = jnp.repeat(xf, k, axis=0)                         # [T_loc*k, d]
    src = jnp.zeros((T_loc * k,), jnp.int32)               # axis idx wins

    def experts_fn(slabs):                                 # [E_loc, C, d]
        return _expert_ffn(slabs, params["w_in"], params["w_out"], act)

    y, plan = fabric.transfer(xk, dst, src, apply_fn=experts_fn,
                              weights=w, registers=registers)
    y = y.reshape(T_loc, k, d).sum(axis=1).reshape(B_loc, S, d)

    me = jax.lax.axis_index(axis_name)
    # top_k destinations are always in [0, E); mode="drop" states the OOB
    # policy outright instead of a clip that would alias onto expert E-1.
    local_counts = jax.lax.psum(
        jnp.zeros((E,), jnp.int32).at[dst].add(
            (plan.keep & (dst // E_loc == me)).astype(jnp.int32),
            mode="drop"),
        axis_name)                                         # [E] per-port
    local = jnp.sum(local_counts)
    offered = jnp.asarray(T_loc * k * n_shards, jnp.int32)
    granted = jnp.sum(plan.counts)
    frac_tokens = (plan.counts / (T_loc * n_shards * k)).astype(jnp.float32)
    frac_probs = (jax.lax.psum(jnp.sum(probs, axis=0), axis_name)
                  / (T_loc * n_shards))
    aux_loss = E * jnp.sum(frac_tokens * frac_probs)
    stats = {
        "aux_loss": aux_loss,
        "dropped": offered - granted,
        "iso_dropped": plan.drops[ErrorCode.INVALID_DEST],
        "capacity": jnp.asarray(cap),
        "counts": plan.counts,
        "offered_packets": offered,
        "granted_packets": granted,
        "local_packets": local,
        "remote_packets": granted - local,
        "local_counts": local_counts,
        "remote_counts": plan.counts - local_counts,
    }
    return y, stats


def moe_apply_sharded_reference(params, x: jax.Array, moe: MoEConfig,
                                act: str, *, n_shards: int,
                                registers=None,
                                expert_mask: Optional[jax.Array] = None,
                                capacity: Optional[int] = None
                                ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Single-device oracle for :func:`moe_apply_sharded`.

    Same router, same register file, same stats — but the whole batch on
    one device through the *reference* backend, with each token's source
    port set to the shard that would own it (batch is laid out
    shard-major, exactly the shard_map partition).  The sharded path must
    match this bit-for-bit on plans and to float tolerance on outputs;
    the forced-4-device tests pin that.
    """
    from repro.core.registers import CrossbarRegisters, ErrorCode

    E, k = moe.n_experts, moe.top_k
    B, S, d = x.shape
    T = B * S
    if B % n_shards or E % n_shards:
        raise ValueError(f"batch {B} and n_experts {E} must both divide "
                         f"into {n_shards} shards")
    T_loc = T // n_shards
    E_loc = E // n_shards
    cap = capacity if capacity is not None else expert_capacity(T, moe)
    if registers is None:
        registers = CrossbarRegisters.create(E, capacity=cap)
    xf = x.reshape(T, d)
    dst, w, probs = _moe_router(params, xf, moe, expert_mask)

    fabric, _ = _group_fabric(E, cap, "reference")
    xk = jnp.repeat(xf, k, axis=0)
    src = jnp.repeat(jnp.arange(n_shards, dtype=jnp.int32), T_loc * k)

    def experts_fn(slabs):                                 # [E, C, d]
        return _expert_ffn(slabs, params["w_in"], params["w_out"], act)

    y, plan = fabric.transfer(xk, dst, src, apply_fn=experts_fn,
                              weights=w, registers=registers)
    y = y.reshape(T, k, d).sum(axis=1).reshape(B, S, d)

    # top_k destinations are always in [0, E); mode="drop" states the OOB
    # policy outright instead of a clip that would alias onto expert E-1.
    local_counts = jnp.zeros((E,), jnp.int32).at[dst].add(
        (plan.keep & (dst // E_loc == src)).astype(jnp.int32), mode="drop")
    local = jnp.sum(local_counts)
    offered = jnp.asarray(T * k, jnp.int32)
    granted = jnp.sum(plan.counts)
    frac_tokens = (plan.counts / (T * k)).astype(jnp.float32)
    aux_loss = E * jnp.sum(frac_tokens * jnp.mean(probs, axis=0))
    stats = {
        "aux_loss": aux_loss,
        "dropped": offered - granted,
        "iso_dropped": plan.drops[ErrorCode.INVALID_DEST],
        "capacity": jnp.asarray(cap),
        "counts": plan.counts,
        "offered_packets": offered,
        "granted_packets": granted,
        "local_packets": local,
        "remote_packets": granted - local,
        "local_counts": local_counts,
        "remote_counts": plan.counts - local_counts,
    }
    return y, stats


def moe_forward_sharded(params, x: jax.Array, moe: MoEConfig, act: str, *,
                        mesh, axis_name: str = "expert", registers=None,
                        expert_mask: Optional[jax.Array] = None,
                        capacity: Optional[int] = None,
                        kernel_mode: Optional[str] = None
                        ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """The model-side shard_map wrapper around :func:`moe_apply_sharded`.

    Shards ``x`` on its batch dim and the expert-indexed params over
    ``axis_name``; the register file and router weights stay replicated.
    Jit this (with ``registers`` as an argument!) and reconfiguration is
    value-only: ``jax.jit(lambda p, r, xx: moe_forward_sharded(p, xx, ...,
    registers=r))`` compiles once per shape and every ``Shell.post`` after
    that re-routes without a retrace.
    """
    import functools as _ft

    from jax.sharding import PartitionSpec as P

    from repro.core.registers import CrossbarRegisters

    shard_map = getattr(jax, "shard_map", None)
    if shard_map is None:
        from jax.experimental.shard_map import shard_map
    n = mesh.shape[axis_name]
    T = x.shape[0] * x.shape[1]
    cap = capacity if capacity is not None else expert_capacity(T, moe)
    if registers is None:
        registers = CrossbarRegisters.create(moe.n_experts, capacity=cap)
    pspec = {"w_router": P(), "w_in": P(axis_name), "w_out": P(axis_name)}
    in_specs = [pspec, P(axis_name), P()]
    args = [params, x, registers]
    if expert_mask is not None:
        in_specs.append(P())
        args.append(expert_mask)

    @_ft.partial(shard_map, mesh=mesh, in_specs=tuple(in_specs),
                 out_specs=(P(axis_name), P()))
    def run(p, xs, regs, *mask):
        return moe_apply_sharded(
            p, xs, moe, act, registers=regs, axis_name=axis_name,
            expert_mask=mask[0] if mask else None, capacity=cap,
            kernel_mode=kernel_mode)

    return run(*args)
