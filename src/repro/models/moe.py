"""Mixture-of-Experts layer routed through the paper's crossbar mechanism.

The mapping is exact, not an analogy:

- *sources* are token groups (the data-parallel regions a batch shard came
  from — the crossbar's master ports),
- *destinations* are experts (slave ports),
- the *WRR package quota* per (source, destination) pair is the per-group
  expert capacity ``C`` — bandwidth allocation in packages (§IV-E.1),
- *isolation masks* restrict which experts a tenant's tokens may reach
  (§IV-E.2), enforced inside the dispatch exactly like the one-hot-AND,
- over-quota packets are dropped with the paper's error codes and surface in
  the router's drop statistics (the register file's status read-back).

Grouped dense formulation (Switch/Mesh-TF style): groups keep the dispatch
tensor O(G * Tg^2) instead of O(T^2); each group independently enforces the
pairwise quota — which is precisely ``pairwise_dispatch_plan`` vmapped over
groups. Group size is a tunable (perf hillclimb lever).
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ParamDef
from repro.models.config import MoEConfig


def moe_defs(d_model: int, d_ff: int, moe: MoEConfig, act: str) -> Dict[str, ParamDef]:
    f_in = 2 * d_ff if act in ("swiglu", "geglu") else d_ff
    return {
        "w_router": ParamDef((d_model, moe.n_experts), ("fsdp", None)),
        "w_in": ParamDef((moe.n_experts, d_model, f_in), (None, "fsdp", "tp")),
        "w_out": ParamDef((moe.n_experts, d_ff, d_model), (None, "tp", "fsdp")),
    }


def expert_capacity(group_tokens: int, moe: MoEConfig, multiple: int = 8) -> int:
    c = math.ceil(moe.capacity_factor * group_tokens * moe.top_k / moe.n_experts)
    return max(multiple, math.ceil(c / multiple) * multiple)


def moe_apply(params, x: jax.Array, moe: MoEConfig, act: str, *,
              group_size: int = 1024,
              expert_mask: Optional[jax.Array] = None,
              dispatch_impl: str = "dense"
              ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """x: [B, S, d] -> (y [B, S, d], stats).

    ``expert_mask``: optional [E] bool — the tenant's allowed-destinations
    register; disallowed experts receive no traffic and their packets are
    dropped (INVALID_DEST analogue), surfacing in ``stats['iso_dropped']``.

    ``dispatch_impl``: "dense" is the Mesh-TF one-hot matmul formulation
    (the faithful baseline — the crossbar's selection matrix realised on
    the MXU). Its dispatch/combine einsums cost 2*T*k*E*C*d FLOPs and an
    O(T*E*C) selection tensor — ~60x the expert matmuls at pod scale.
    "gather" routes by indexed scatter/gather instead: O(T*k*d) data
    movement and no selection tensor (§Perf iteration "moe-gather").
    Identical packet semantics: same ranks, same WRR quota drops.

    Any other value names a ``repro.fabric`` backend ("reference",
    "pallas", or a registered custom): the layer then routes every group
    through a ``Fabric.transfer`` round-trip — experts are crossbar slave
    ports, ``expert_mask`` is the isolation row, capacity is the slab
    depth — so the MoE data plane and the shell's interconnect share one
    implementation (and one plan semantics) instead of re-deriving ranks
    here.
    """
    if dispatch_impl == "gather":
        return moe_apply_gather(params, x, moe, act, group_size=group_size,
                                expert_mask=expert_mask)
    if dispatch_impl != "dense":
        return moe_apply_fabric(params, x, moe, act, group_size=group_size,
                                expert_mask=expert_mask,
                                backend=dispatch_impl)
    B, S, d = x.shape
    E, k = moe.n_experts, moe.top_k
    T = B * S
    g = min(group_size, T)
    G = T // g
    assert G * g == T, f"tokens {T} not divisible by group size {g}"
    xf = x.reshape(G, g, d)

    logits = jnp.einsum("gtd,de->gte", xf, params["w_router"]).astype(jnp.float32)
    if expert_mask is not None:
        logits = jnp.where(expert_mask[None, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)                    # [G, g, E]
    top_p, top_e = jax.lax.top_k(probs, k)                     # [G, g, k]
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # --- crossbar dispatch plan: per-(group, expert) package ranks -------
    dst = top_e.reshape(G, g * k)                              # packets
    w = top_p.reshape(G, g * k).astype(x.dtype)
    cap = expert_capacity(g, moe)
    e_oh = jax.nn.one_hot(dst, E, dtype=jnp.int32)             # [G, gk, E]
    rank = jnp.cumsum(e_oh, axis=1) - e_oh
    rank = jnp.take_along_axis(rank, dst[..., None], axis=2)[..., 0]
    keep = rank < cap                                          # WRR quota
    if expert_mask is not None:
        iso_ok = expert_mask[dst]
        keep &= iso_ok
        iso_dropped = jnp.sum(~iso_ok)
    else:
        iso_dropped = jnp.zeros((), jnp.int32)
    slot = jnp.where(keep, rank, 0)

    sel = (jax.nn.one_hot(dst, E, dtype=x.dtype)
           * keep[..., None].astype(x.dtype))                  # [G, gk, E]
    slot_oh = jax.nn.one_hot(slot, cap, dtype=x.dtype)         # [G, gk, C]
    disp = sel[..., :, None] * slot_oh[..., None, :]           # [G, gk, E, C]

    xk = jnp.repeat(xf, k, axis=1)                             # [G, gk, d]
    xe = jnp.einsum("gtec,gtd->gecd", disp, xk)                # [G, E, C, d]

    h = jnp.einsum("gecd,edf->gecf", xe, params["w_in"])
    if act in ("swiglu", "geglu"):
        gate, up = jnp.split(h, 2, axis=-1)
        a = jax.nn.silu(gate.astype(jnp.float32)) if act == "swiglu" \
            else jax.nn.gelu(gate.astype(jnp.float32))
        h = (a * up.astype(jnp.float32)).astype(x.dtype)
    else:
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    ye = jnp.einsum("gecf,efd->gecd", h, params["w_out"])      # [G, E, C, d]

    comb = disp * w[..., None, None]
    y = jnp.einsum("gtec,gecd->gtd", comb, ye)                 # [G, gk, d]
    y = y.reshape(G, g, k, d).sum(axis=2).reshape(B, S, d)

    # --- router statistics (load-balance aux loss + drop read-back) ------
    frac_tokens = jnp.mean(sel, axis=(0, 1))                   # [E]
    frac_probs = jnp.mean(probs, axis=(0, 1))                  # [E]
    aux_loss = E * jnp.sum(frac_tokens.astype(jnp.float32) * frac_probs)
    stats = {
        "aux_loss": aux_loss,
        "dropped": jnp.sum(~keep),
        "iso_dropped": iso_dropped,
        "capacity": jnp.asarray(cap),
    }
    return y, stats


def moe_apply_gather(params, x: jax.Array, moe: MoEConfig, act: str, *,
                     group_size: int = 1024,
                     expert_mask: Optional[jax.Array] = None
                     ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Gather/scatter MoE dispatch — same grant semantics, no dense
    selection tensor.

    Packet slot assignment is identical to the dense path (rank within the
    (group, expert) stream == the WRR package counter); the slab is filled
    with ``.at[slot].add`` (unique slots, so add == set) and results return
    with ``take_along_axis``. FLOPs: experts only. Bytes: O(T*k*d).
    """
    B, S, d = x.shape
    E, k = moe.n_experts, moe.top_k
    T = B * S
    g = min(group_size, T)
    G = T // g
    assert G * g == T, f"tokens {T} not divisible by group size {g}"
    xf = x.reshape(G, g, d)

    logits = jnp.einsum("gtd,de->gte", xf, params["w_router"]).astype(jnp.float32)
    if expert_mask is not None:
        logits = jnp.where(expert_mask[None, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    dst = top_e.reshape(G, g * k)
    w = top_p.reshape(G, g * k).astype(x.dtype)
    cap = expert_capacity(g, moe)
    e_oh = jax.nn.one_hot(dst, E, dtype=jnp.int32)
    rank = jnp.cumsum(e_oh, axis=1) - e_oh
    rank = jnp.take_along_axis(rank, dst[..., None], axis=2)[..., 0]
    keep = rank < cap
    if expert_mask is not None:
        iso_ok = expert_mask[dst]
        keep &= iso_ok
        iso_dropped = jnp.sum(~iso_ok)
    else:
        iso_dropped = jnp.zeros((), jnp.int32)

    # --- indexed dispatch: packet -> (expert, slot) flat address ---------
    # Dropped packets write to a trash slot (index E*cap) that is sliced off.
    slot_addr = jnp.where(keep, dst * cap + jnp.where(keep, rank, 0),
                          E * cap)                       # [G, gk]
    xk = jnp.repeat(xf, k, axis=1)                       # [G, gk, d]

    def fill(slabs_g, addr_g, xk_g):
        return slabs_g.at[addr_g].add(xk_g.astype(slabs_g.dtype))

    slabs = jnp.zeros((G, E * cap + 1, d), x.dtype)
    slabs = jax.vmap(fill)(slabs, slot_addr, xk)
    xe = slabs[:, :E * cap].reshape(G, E, cap, d)

    h = jnp.einsum("gecd,edf->gecf", xe, params["w_in"])
    if act in ("swiglu", "geglu"):
        gate, up = jnp.split(h, 2, axis=-1)
        a = jax.nn.silu(gate.astype(jnp.float32)) if act == "swiglu" \
            else jax.nn.gelu(gate.astype(jnp.float32))
        h = (a * up.astype(jnp.float32)).astype(x.dtype)
    else:
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    ye = jnp.einsum("gecf,efd->gecd", h, params["w_out"])  # [G, E, cap, d]

    # --- indexed combine: gather each packet's result, weight, sum top-k -
    ye_flat = ye.reshape(G, E * cap, d)
    ye_flat = jnp.concatenate(
        [ye_flat, jnp.zeros((G, 1, d), ye.dtype)], axis=1)  # trash slot
    back = jnp.take_along_axis(ye_flat, slot_addr[..., None], axis=1)
    back = back * (w * keep.astype(w.dtype))[..., None]
    y = back.reshape(G, g, k, d).sum(axis=2).reshape(B, S, d)

    sel_frac = jnp.mean(
        jax.nn.one_hot(dst, E, dtype=jnp.float32)
        * keep[..., None].astype(jnp.float32), axis=(0, 1))
    frac_probs = jnp.mean(probs, axis=(0, 1))
    aux_loss = E * jnp.sum(sel_frac * frac_probs)
    stats = {
        "aux_loss": aux_loss,
        "dropped": jnp.sum(~keep),
        "iso_dropped": iso_dropped,
        "capacity": jnp.asarray(cap),
    }
    return y, stats


@functools.lru_cache(maxsize=None)
def _group_fabric(n_experts: int, capacity: int, backend: str):
    """One cached fabric (and its jit caches) per MoE geometry.

    The fabric reads its registers through a mutable cell so the caller
    can swap in the tenant's isolation mask per forward pass — values
    steer routing, the compiled dispatch/combine programs are reused
    across calls (and across layers sharing a geometry)."""
    from repro.core.registers import CrossbarRegisters
    from repro.fabric import Fabric
    cell = {"regs": CrossbarRegisters.create(n_experts, capacity=capacity)}
    fabric = Fabric(lambda: cell["regs"], backend=backend, capacity=capacity)
    return fabric, cell


def moe_apply_fabric(params, x: jax.Array, moe: MoEConfig, act: str, *,
                     group_size: int = 1024,
                     expert_mask: Optional[jax.Array] = None,
                     backend: str = "reference"
                     ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """MoE dispatch as a ``repro.fabric`` transfer — one data-plane impl.

    Per group: tokens are packets from one master port, experts are the
    slave ports, ``expert_mask`` is the tenant isolation row, and the
    expert capacity is the receive-slab depth.  The whole
    plan/dispatch/expert/combine round-trip is a single vmapped
    ``Fabric.transfer`` with the expert FFN as ``apply_fn`` — so whichever
    backend serves the shell (reference oracle, blockwise Pallas kernels)
    also serves the MoE layer, with the paper's error codes as the drop
    statistics.
    """
    from repro.core.registers import ErrorCode

    B, S, d = x.shape
    E, k = moe.n_experts, moe.top_k
    T = B * S
    g = min(group_size, T)
    G = T // g
    assert G * g == T, f"tokens {T} not divisible by group size {g}"
    xf = x.reshape(G, g, d)

    logits = jnp.einsum("gtd,de->gte", xf, params["w_router"]).astype(jnp.float32)
    if expert_mask is not None:
        logits = jnp.where(expert_mask[None, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    dst = top_e.reshape(G, g * k)
    w = top_p.reshape(G, g * k).astype(x.dtype)
    cap = expert_capacity(g, moe)

    fabric, cell = _group_fabric(E, cap, backend)
    canonical = cell["regs"]
    # Fully specify the isolation mask every call — the cell is shared
    # across calls (and tenants) on this geometry, so nothing may inherit
    # a previous call's mask; restored below so no (possibly traced) mask
    # outlives this forward pass.
    allowed = (jnp.broadcast_to(expert_mask[None, :], (E, E))
               if expert_mask is not None
               else jnp.ones((E, E), bool))
    cell["regs"] = dataclasses.replace(canonical, allowed=allowed)
    src = jnp.zeros((g * k,), jnp.int32)

    def experts_fn(slabs):                                 # [E, C, d]
        h = jnp.einsum("ecd,edf->ecf", slabs, params["w_in"])
        if act in ("swiglu", "geglu"):
            gate, up = jnp.split(h, 2, axis=-1)
            a = jax.nn.silu(gate.astype(jnp.float32)) if act == "swiglu" \
                else jax.nn.gelu(gate.astype(jnp.float32))
            h = (a * up.astype(jnp.float32)).astype(slabs.dtype)
        else:
            h = jax.nn.gelu(h.astype(jnp.float32)).astype(slabs.dtype)
        return jnp.einsum("ecf,efd->ecd", h, params["w_out"])

    def one_group(xg, dg, wg):
        # dispatch/combine are the fabric's shape-cached jits; the expert
        # compute stays in the caller's trace (params close over nothing
        # that would key a recompile).
        xk = jnp.repeat(xg, k, axis=0)                     # [gk, d]
        slabs, plan = fabric.dispatch(xk, dg, src)
        return fabric.combine(experts_fn(slabs), plan, weights=wg), plan

    try:
        y, plans = jax.vmap(one_group)(xf, dst, w)         # y [G, gk, d]
    finally:
        cell["regs"] = canonical
    y = y.reshape(G, g, k, d).sum(axis=2).reshape(B, S, d)

    frac_tokens = (jnp.sum(plans.counts, axis=0) / (G * g * k)
                   ).astype(jnp.float32)
    frac_probs = jnp.mean(probs, axis=(0, 1))
    aux_loss = E * jnp.sum(frac_tokens * frac_probs)
    stats = {
        "aux_loss": aux_loss,
        "dropped": jnp.sum(~plans.keep),
        "iso_dropped": jnp.sum(plans.drops[:, ErrorCode.INVALID_DEST]),
        "capacity": jnp.asarray(cap),
    }
    return y, stats
