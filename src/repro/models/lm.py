"""Model assembly: dense / MoE / VLM / enc-dec / SSM / hybrid LMs.

One uniform contract per family (``Model``):

- ``init(key)``                 — parameters (stacked [L, ...] for lax.scan)
- ``param_specs(multi_pod)``    — PartitionSpec tree (same structure)
- ``loss(params, batch)``       — training objective (chunked vocab xent)
- ``prefill(params, batch)``    — full-sequence forward -> last-token logits
- ``decode_step(params, state, tokens)`` — one token with cached state
- ``decode_state_shapes(shape, multi_pod)`` — ShapeDtypeStructs + specs for
  the dry-run (no allocation)

Design notes (see DESIGN.md §4):
- layers run under ``jax.lax.scan`` with stacked params, so the compiled HLO
  holds ONE block regardless of depth (compile-time and HLO size sanity on a
  1-core host, and the unit XLA pipelines collectives against);
- remat policy is configurable per arch (train only);
- the LM loss is computed in sequence chunks so the [B, S, V] logits tensor
  is never materialised (vocabs here reach 256k);
- normalisation/positional encoding are unified to RMSNorm + RoPE across the
  zoo (documented adaptation); dims, attention patterns (GQA/SWA/MQA), MoE
  routing, SSD and RG-LRU recurrences are faithful.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import attention as attn
from repro.models import mlp as mlp_mod
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import ssm as ssm_mod
from repro.models.common import (ParamDef, dtype_of, init_params,
                                 logical_to_spec, ones_init, rms_norm,
                                 scan_or_unroll, softmax_xent, spec_tree)
from repro.models.config import ModelConfig, ShapeConfig

Params = Any


# ======================================================================
# helpers
# ======================================================================
def stack_defs(defs: Dict[str, Any], n: int) -> Dict[str, Any]:
    return jax.tree.map(
        lambda d: ParamDef((n,) + d.shape, ("layers",) + d.spec, d.init, d.scale),
        defs, is_leaf=lambda x: isinstance(x, ParamDef))


def attn_defs(cfg: ModelConfig) -> Dict[str, ParamDef]:
    d, H, Kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    kv_ax = "tp" if cfg.kv_shard == "tp" else None
    out = {
        "wq": ParamDef((d, H * hd), ("fsdp", "tp")),
        "wk": ParamDef((d, Kv * hd), ("fsdp", kv_ax)),
        "wv": ParamDef((d, Kv * hd), ("fsdp", kv_ax)),
        "wo": ParamDef((H * hd, d), ("tp", "fsdp")),
    }
    if cfg.qkv_bias:
        out.update({
            "bq": ParamDef((H * hd,), ("tp",), init=lambda k, s, t, sc: jnp.zeros(s, t)),
            "bk": ParamDef((Kv * hd,), (kv_ax,), init=lambda k, s, t, sc: jnp.zeros(s, t)),
            "bv": ParamDef((Kv * hd,), (kv_ax,), init=lambda k, s, t, sc: jnp.zeros(s, t)),
        })
    return out


def qkv(params, x: jax.Array, cfg: ModelConfig, positions: jax.Array):
    """Project + rope. Returns q [B,S,H,hd], k/v [B,S,Kv,hd] (k post-rope)."""
    B, S, _ = x.shape
    H, Kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = jnp.einsum("bsd,de->bse", x, params["wq"])
    k = jnp.einsum("bsd,de->bse", x, params["wk"])
    v = jnp.einsum("bsd,de->bse", x, params["wv"])
    if cfg.qkv_bias:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, Kv, hd)
    v = v.reshape(B, S, Kv, hd)
    q = attn.apply_rope(q, positions, cfg.rope_theta)
    k = attn.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def chunked_lm_loss(h: jax.Array, w_head: jax.Array, labels: jax.Array,
                    true_vocab: int, chunk: int = 512,
                    unroll: bool = False) -> jax.Array:
    """Sequence-chunked vocab xent: never materialises [B, S, V] logits."""
    B, S, d = h.shape
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
    nc = h.shape[1] // chunk
    hc = h.reshape(B, nc, chunk, d).swapaxes(0, 1)
    lc = labels.reshape(B, nc, chunk).swapaxes(0, 1)
    valid = (jnp.arange(nc * chunk).reshape(nc, chunk) < S)

    @jax.checkpoint
    def body(tot, inp):
        hh, ll, vv = inp
        logits = jnp.einsum("bsd,dv->bsv", hh, w_head)
        per_tok = _xent_per_token(logits, ll, true_vocab)
        return tot + jnp.sum(per_tok * vv[None, :]), None

    tot, _ = scan_or_unroll(body, jnp.zeros((), jnp.float32),
                            (hc, lc, valid), unroll=unroll)
    return tot / (B * S)


def _xent_per_token(logits, labels, true_vocab):
    logits = logits.astype(jnp.float32)
    if logits.shape[-1] > true_vocab:
        mask = jnp.arange(logits.shape[-1]) < true_vocab
        logits = jnp.where(mask, logits, jnp.finfo(jnp.float32).min)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return logz - gold


def remat_wrap(fn, policy: str):
    if policy == "nothing":
        return fn
    if policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)       # "full": save nothing


def batch_axes(global_batch: int, multi_pod: bool) -> Optional[Any]:
    """Batch sharding that respects divisibility (B=1 long-decode stays
    replicated on the data axis)."""
    need = 32 if multi_pod else 16
    if global_batch % need == 0:
        return ("pod", "data") if multi_pod else "data"
    if global_batch % 16 == 0 and multi_pod:
        return "data"
    return None


# ======================================================================
# Decode state (uniform across families)
# ======================================================================
@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class DecodeState:
    pos: jax.Array                              # [] int32 — next position
    kv_k: Optional[jax.Array] = None            # [La, B, Sc, Kv, hd]
    kv_v: Optional[jax.Array] = None
    kv_pos: Optional[jax.Array] = None          # [B, Sc]
    cross_k: Optional[jax.Array] = None         # [L, B, F, Kv, hd] (enc-dec)
    cross_v: Optional[jax.Array] = None
    ssm_state: Optional[jax.Array] = None       # [L, B, H, P, N]
    conv_tail: Optional[jax.Array] = None       # [L, B, W-1, convdim]
    rec_h: Optional[jax.Array] = None           # [Lr, B, lru]
    rec_tail: Optional[jax.Array] = None        # [Lr, B, 3, lru]


# ======================================================================
# Base class
# ======================================================================
class LMBase:
    def __init__(self, cfg: ModelConfig):
        cfg.validate()
        self.cfg = cfg
        self.dtype = dtype_of(cfg.dtype)
        # Batch mesh axis for activation sharding constraints. Set by the
        # launcher (build_step) when tracing under a mesh; None disables.
        # Without these constraints the SPMD partitioner resolves the
        # remat-boundary activations inconsistently between the forward and
        # the rematted backward copy and REPLICATES the recompute over the
        # data axis (observed: 2.1x per-layer FLOPs on the 16x16 pod) — see
        # EXPERIMENTS.md §Perf iteration "activation sharding constraints".
        self.batch_axis: Optional[Any] = None

    def constrain(self, x: jax.Array) -> jax.Array:
        """Pin a [B, S, d] activation to (batch-sharded, replicated, ...)."""
        if self.batch_axis is None:
            return x
        spec = P(self.batch_axis, *([None] * (x.ndim - 1)))
        return jax.lax.with_sharding_constraint(x, spec)

    # ---- embedding / head ------------------------------------------------
    def _embed_defs(self) -> Dict[str, Any]:
        cfg = self.cfg
        out = {
            "embed": ParamDef((cfg.vocab_padded, cfg.d_model), ("tp", "fsdp")),
            "final_norm": ParamDef((cfg.d_model,), (None,), init=ones_init),
        }
        if not cfg.tied_embeddings:
            out["lm_head"] = ParamDef((cfg.d_model, cfg.vocab_padded),
                                      ("fsdp", "tp"))
        return out

    def _head_weight(self, params):
        if self.cfg.tied_embeddings:
            return params["embed"].T
        return params["lm_head"]

    def _embed(self, params, tokens):
        return jnp.take(params["embed"], tokens, axis=0)

    # ---- public API -------------------------------------------------------
    def param_defs(self) -> Dict[str, Any]:
        raise NotImplementedError

    def init(self, key: jax.Array) -> Params:
        return init_params(self.param_defs(), key, self.dtype)

    def param_specs(self, multi_pod: bool) -> Params:
        return spec_tree(self.param_defs(), multi_pod=multi_pod)

    def param_shapes(self) -> Params:
        return jax.tree.map(
            lambda d: jax.ShapeDtypeStruct(d.shape, self.dtype),
            self.param_defs(), is_leaf=lambda x: isinstance(x, ParamDef))

    def n_params(self) -> int:
        import math
        return sum(math.prod(d.shape)
                   for d in jax.tree.leaves(
                       self.param_defs(),
                       is_leaf=lambda x: isinstance(x, ParamDef)))

    # ---- inputs -------------------------------------------------------
    def input_shapes(self, shape: ShapeConfig, multi_pod: bool
                     ) -> Tuple[Dict[str, jax.ShapeDtypeStruct], Dict[str, P]]:
        """(ShapeDtypeStructs, PartitionSpecs) for the data batch."""
        B, S = shape.global_batch, shape.seq_len
        bspec = batch_axes(B, multi_pod)
        structs = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        specs = {"tokens": P(bspec, None)}
        if shape.kind == "train":
            structs["labels"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
            specs["labels"] = P(bspec, None)
        if shape.kind == "decode":
            structs["tokens"] = jax.ShapeDtypeStruct((B, 1), jnp.int32)
            specs["tokens"] = P(bspec, None)
        if self.cfg.n_vision_patches:
            structs["patches"] = jax.ShapeDtypeStruct(
                (B, self.cfg.n_vision_patches, self.cfg.d_model), self.dtype)
            specs["patches"] = P(bspec, None, None)
        if self.cfg.family == "encdec":
            structs["frames"] = jax.ShapeDtypeStruct(
                (B, self.cfg.encoder_len, self.cfg.d_model), self.dtype)
            specs["frames"] = P(bspec, None, None)
        return structs, specs

    def decode_state_shapes(self, shape, multi_pod):
        raise NotImplementedError

    # subclasses implement
    def loss(self, params, batch):
        raise NotImplementedError

    def prefill(self, params, batch):
        raise NotImplementedError

    def decode_step(self, params, state: DecodeState, batch):
        raise NotImplementedError


# ======================================================================
# Dense / MoE / VLM decoder-only LM
# ======================================================================
class DenseLM(LMBase):
    """Decoder-only transformer: GQA (+optional SWA window, qkv-bias), with
    per-layer MLP or crossbar-dispatched MoE."""

    def _layer_defs(self) -> Dict[str, Any]:
        cfg = self.cfg
        d = {
            "norm1": ParamDef((cfg.d_model,), (None,), init=ones_init),
            "attn": attn_defs(cfg),
            "norm2": ParamDef((cfg.d_model,), (None,), init=ones_init),
        }
        if cfg.moe is not None:
            d["moe"] = moe_mod.moe_defs(cfg.d_model, cfg.d_ff, cfg.moe,
                                        cfg.mlp_act)
        else:
            d["mlp"] = mlp_mod.mlp_defs(cfg.d_model, cfg.d_ff, cfg.mlp_act)
        return d

    def param_defs(self) -> Dict[str, Any]:
        out = self._embed_defs()
        out["layers"] = stack_defs(self._layer_defs(), self.cfg.n_layers)
        return out

    # ---- forward ------------------------------------------------------
    def _block(self, lp, x, positions, moe_group: int):
        cfg = self.cfg
        x = self.constrain(x)
        h = rms_norm(x, lp["norm1"], cfg.norm_eps)
        q, k, v = qkv(lp["attn"], h, cfg, positions)
        o = attn.attention_prefill(q, k, v, causal=True,
                                   window=cfg.attn_window,
                                   unroll=not cfg.scan_layers)
        o = jnp.einsum("bse,ed->bsd",
                       o.reshape(o.shape[0], o.shape[1], -1), lp["attn"]["wo"])
        x = x + o
        h2 = rms_norm(x, lp["norm2"], cfg.norm_eps)
        if cfg.moe is not None:
            y, stats = moe_mod.moe_apply(lp["moe"], h2, cfg.moe, cfg.mlp_act,
                                         group_size=moe_group,
                                         dispatch_impl=cfg.moe.dispatch,
                                         kernel_mode=cfg.moe.kernel_mode)
            aux = stats["aux_loss"]
        else:
            y = mlp_mod.mlp_apply(lp["mlp"], h2, cfg.mlp_act)
            aux = jnp.zeros((), jnp.float32)
        return x + y, aux

    def _backbone(self, params, x, positions, *, train: bool,
                  moe_group: int = 1024):
        cfg = self.cfg

        def body(carry, lp):
            xx, aux = carry
            xx, a = self._block(lp, xx, positions, moe_group)
            return (xx, aux + a), None

        fn = remat_wrap(body, cfg.remat if train else "nothing")
        (x, aux), _ = scan_or_unroll(fn, (x, jnp.zeros((), jnp.float32)),
                                     params["layers"],
                                     unroll=not cfg.scan_layers)
        x = self.constrain(x)
        return rms_norm(x, params["final_norm"], cfg.norm_eps), aux

    def _inputs_embed(self, params, batch):
        x = self._embed(params, batch["tokens"])
        if self.cfg.n_vision_patches and "patches" in batch:
            Pn = self.cfg.n_vision_patches
            x = jnp.concatenate([batch["patches"].astype(x.dtype),
                                 x[:, Pn:]], axis=1)
        return x

    def loss(self, params, batch):
        cfg = self.cfg
        x = self._inputs_embed(params, batch)
        positions = jnp.arange(x.shape[1])[None, :]
        h, aux = self._backbone(params, x, positions, train=True,
                                moe_group=min(1024, x.shape[0] * x.shape[1]))
        lm = chunked_lm_loss(h, self._head_weight(params), batch["labels"],
                             cfg.vocab, unroll=not cfg.scan_layers)
        return lm + 0.01 * aux

    def prefill(self, params, batch):
        x = self._inputs_embed(params, batch)
        positions = jnp.arange(x.shape[1])[None, :]
        h, _ = self._backbone(params, x, positions, train=False)
        logits = jnp.einsum("bd,dv->bv", h[:, -1], self._head_weight(params))
        return logits

    # ---- decode -------------------------------------------------------
    def decode_state_shapes(self, shape: ShapeConfig, multi_pod: bool):
        cfg = self.cfg
        B = shape.global_batch
        slots = min(cfg.attn_window, shape.seq_len) if cfg.attn_window \
            else shape.seq_len
        bspec = batch_axes(B, multi_pod)
        kv_shape = (cfg.n_layers, B, slots, cfg.n_kv_heads, cfg.hd)
        structs = DecodeState(
            pos=jax.ShapeDtypeStruct((), jnp.int32),
            kv_k=jax.ShapeDtypeStruct(kv_shape, self.dtype),
            kv_v=jax.ShapeDtypeStruct(kv_shape, self.dtype),
            kv_pos=jax.ShapeDtypeStruct((B, slots), jnp.int32))
        specs = DecodeState(
            pos=P(),
            kv_k=P(None, bspec, "model", None, None),
            kv_v=P(None, bspec, "model", None, None),
            kv_pos=P(bspec, "model"))
        return structs, specs

    def init_decode_state(self, batch: int, max_len: int) -> DecodeState:
        cfg = self.cfg
        slots = min(cfg.attn_window, max_len) if cfg.attn_window else max_len
        z = lambda *s: jnp.zeros(s, self.dtype)
        return DecodeState(
            pos=jnp.zeros((), jnp.int32),
            kv_k=z(cfg.n_layers, batch, slots, cfg.n_kv_heads, cfg.hd),
            kv_v=z(cfg.n_layers, batch, slots, cfg.n_kv_heads, cfg.hd),
            kv_pos=jnp.full((batch, slots), -1, jnp.int32))

    def decode_step(self, params, state: DecodeState, batch):
        cfg = self.cfg
        tok = batch["tokens"]                         # [B, 1]
        x = self._embed(params, tok)
        pos = state.pos
        positions = jnp.full((x.shape[0], 1), pos, jnp.int32)

        def body(xx, inp):
            lp, ck, cv = inp
            h = rms_norm(xx, lp["norm1"], cfg.norm_eps)
            q, k, v = qkv(lp["attn"], h, cfg, positions)
            ck, cv, kvpos = attn.cache_write(ck, cv, state.kv_pos, k, v, pos)
            o = attn.attention_decode(q, ck, cv, kvpos, pos,
                                      window=cfg.attn_window)
            o = jnp.einsum("bse,ed->bsd",
                           o.reshape(o.shape[0], 1, -1), lp["attn"]["wo"])
            xx = xx + o
            h2 = rms_norm(xx, lp["norm2"], cfg.norm_eps)
            if cfg.moe is not None:
                y, _ = moe_mod.moe_apply(lp["moe"], h2, cfg.moe, cfg.mlp_act,
                                         group_size=h2.shape[0],
                                         dispatch_impl=cfg.moe.dispatch,
                                         kernel_mode=cfg.moe.kernel_mode)
            else:
                y = mlp_mod.mlp_apply(lp["mlp"], h2, cfg.mlp_act)
            return xx + y, (ck, cv)

        x, (ck, cv) = scan_or_unroll(
            body, x, (params["layers"], state.kv_k, state.kv_v),
            unroll=not cfg.scan_layers)
        # kv_pos update is layer-independent: recompute once.
        slots = state.kv_k.shape[2]
        slot = (pos % slots).astype(jnp.int32)
        kv_pos = jax.lax.dynamic_update_slice(
            state.kv_pos, jnp.full((x.shape[0], 1), pos, jnp.int32), (0, slot))
        h = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = jnp.einsum("bd,dv->bv", h[:, -1], self._head_weight(params))
        new_state = dataclasses.replace(state, pos=pos + 1, kv_k=ck, kv_v=cv,
                                        kv_pos=kv_pos)
        return logits, new_state


# ======================================================================
# Mamba-2 (attention-free SSM)
# ======================================================================
class SSMLM(LMBase):
    def param_defs(self) -> Dict[str, Any]:
        cfg = self.cfg
        layer = {
            "norm": ParamDef((cfg.d_model,), (None,), init=ones_init),
            "mixer": ssm_mod.ssm_defs(cfg.d_model, cfg.ssm),
        }
        out = self._embed_defs()
        out["layers"] = stack_defs(layer, cfg.n_layers)
        return out

    def _backbone(self, params, x, *, train: bool):
        cfg = self.cfg

        def body(xx, lp):
            xx = self.constrain(xx)
            h = rms_norm(xx, lp["norm"], cfg.norm_eps)
            y, _, _ = ssm_mod.ssm_apply(lp["mixer"], h, cfg.ssm,
                                        unroll=not cfg.scan_layers)
            return xx + y, None

        fn = remat_wrap(body, cfg.remat if train else "nothing")
        x, _ = scan_or_unroll(fn, x, params["layers"],
                              unroll=not cfg.scan_layers)
        return rms_norm(x, params["final_norm"], cfg.norm_eps)

    def loss(self, params, batch):
        x = self._embed(params, batch["tokens"])
        h = self._backbone(params, x, train=True)
        return chunked_lm_loss(h, self._head_weight(params), batch["labels"],
                               self.cfg.vocab,
                               unroll=not self.cfg.scan_layers)

    def prefill(self, params, batch):
        x = self._embed(params, batch["tokens"])
        h = self._backbone(params, x, train=False)
        return jnp.einsum("bd,dv->bv", h[:, -1], self._head_weight(params))

    def _state_dims(self):
        cfg = self.cfg
        ssm = cfg.ssm
        H = ssm.n_heads(cfg.d_model)
        conv_dim = ssm.expand * cfg.d_model + 2 * ssm.d_state
        return H, ssm.head_dim, ssm.d_state, conv_dim, ssm.conv_width

    def decode_state_shapes(self, shape: ShapeConfig, multi_pod: bool):
        cfg = self.cfg
        B = shape.global_batch
        H, Pd, N, conv_dim, W = self._state_dims()
        bspec = batch_axes(B, multi_pod)
        structs = DecodeState(
            pos=jax.ShapeDtypeStruct((), jnp.int32),
            ssm_state=jax.ShapeDtypeStruct((cfg.n_layers, B, H, Pd, N),
                                           jnp.float32),
            conv_tail=jax.ShapeDtypeStruct((cfg.n_layers, B, W - 1, conv_dim),
                                           self.dtype))
        specs = DecodeState(
            pos=P(),
            ssm_state=P(None, bspec, "model", None, None),
            conv_tail=P(None, bspec, None, "model"))
        return structs, specs

    def init_decode_state(self, batch: int, max_len: int) -> DecodeState:
        cfg = self.cfg
        H, Pd, N, conv_dim, W = self._state_dims()
        return DecodeState(
            pos=jnp.zeros((), jnp.int32),
            ssm_state=jnp.zeros((cfg.n_layers, batch, H, Pd, N), jnp.float32),
            conv_tail=jnp.zeros((cfg.n_layers, batch, W - 1, conv_dim),
                                self.dtype))

    def decode_step(self, params, state: DecodeState, batch):
        cfg = self.cfg
        x = self._embed(params, batch["tokens"])

        def body(xx, inp):
            lp, st, tail = inp
            h = rms_norm(xx, lp["norm"], cfg.norm_eps)
            y, st2, tail2 = ssm_mod.ssm_apply(lp["mixer"], h, cfg.ssm,
                                              state=st, conv_tail=tail,
                                              decode=True)
            return xx + y, (st2, tail2)

        x, (st, tail) = scan_or_unroll(
            body, x, (params["layers"], state.ssm_state, state.conv_tail),
            unroll=not cfg.scan_layers)
        h = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = jnp.einsum("bd,dv->bv", h[:, -1], self._head_weight(params))
        return logits, dataclasses.replace(state, pos=state.pos + 1,
                                           ssm_state=st, conv_tail=tail)


# ======================================================================
# RecurrentGemma-style hybrid: (rec, rec, local-attn) groups
# ======================================================================
class HybridLM(LMBase):
    """`pattern_rec` RG-LRU blocks then one local-attention block per group;
    trailing non-group layers are recurrent blocks."""

    def __init__(self, cfg: ModelConfig):
        super().__init__(cfg)
        per = cfg.hybrid.pattern_rec + 1
        self.n_groups = cfg.n_layers // per
        self.n_trail = cfg.n_layers - self.n_groups * per
        self.lru = cfg.hybrid.lru_width or cfg.d_model

    def _rec_defs(self):
        cfg = self.cfg
        return {
            "norm1": ParamDef((cfg.d_model,), (None,), init=ones_init),
            "rec": rglru_mod.rglru_defs(cfg.d_model, self.lru),
            "norm2": ParamDef((cfg.d_model,), (None,), init=ones_init),
            "mlp": mlp_mod.mlp_defs(cfg.d_model, cfg.d_ff, cfg.mlp_act),
        }

    def _attn_block_defs(self):
        cfg = self.cfg
        return {
            "norm1": ParamDef((cfg.d_model,), (None,), init=ones_init),
            "attn": attn_defs(cfg),
            "norm2": ParamDef((cfg.d_model,), (None,), init=ones_init),
            "mlp": mlp_mod.mlp_defs(cfg.d_model, cfg.d_ff, cfg.mlp_act),
        }

    def param_defs(self) -> Dict[str, Any]:
        cfg = self.cfg
        group = {
            "rec": stack_defs(self._rec_defs(), cfg.hybrid.pattern_rec),
            "attn_blk": self._attn_block_defs(),
        }
        out = self._embed_defs()
        out["groups"] = stack_defs(group, self.n_groups)
        if self.n_trail:
            out["trail"] = stack_defs(self._rec_defs(), self.n_trail)
        return out

    # ---- block bodies ---------------------------------------------------
    def _rec_block(self, lp, x, h0=None, tail=None, decode=False):
        cfg = self.cfg
        if not decode:
            x = self.constrain(x)
        h = rms_norm(x, lp["norm1"], cfg.norm_eps)
        y, h_last, tail2 = rglru_mod.rglru_block_apply(
            lp["rec"], h, h0=h0, conv_tail=tail, decode=decode)
        x = x + y
        h2 = rms_norm(x, lp["norm2"], cfg.norm_eps)
        return x + mlp_mod.mlp_apply(lp["mlp"], h2, cfg.mlp_act), h_last, tail2

    def _attn_block(self, lp, x, positions):
        cfg = self.cfg
        x = self.constrain(x)
        h = rms_norm(x, lp["norm1"], cfg.norm_eps)
        q, k, v = qkv(lp["attn"], h, cfg, positions)
        o = attn.attention_prefill(q, k, v, causal=True,
                                   window=cfg.hybrid.attn_window,
                                   unroll=not cfg.scan_layers)
        x = x + jnp.einsum("bse,ed->bsd",
                           o.reshape(o.shape[0], o.shape[1], -1),
                           lp["attn"]["wo"])
        h2 = rms_norm(x, lp["norm2"], cfg.norm_eps)
        return x + mlp_mod.mlp_apply(lp["mlp"], h2, cfg.mlp_act)

    def _backbone(self, params, x, positions, *, train: bool):
        cfg = self.cfg

        unroll = not cfg.scan_layers

        def rec_scan(xx, stacked):
            def rbody(c, lp):
                c2, _, _ = self._rec_block(lp, c)
                return c2, None
            out, _ = scan_or_unroll(rbody, xx, stacked, unroll=unroll)
            return out

        def gbody(xx, gp):
            xx = rec_scan(xx, gp["rec"])
            return self._attn_block(gp["attn_blk"], xx, positions), None

        fn = remat_wrap(gbody, cfg.remat if train else "nothing")
        x, _ = scan_or_unroll(fn, x, params["groups"], unroll=unroll)
        if self.n_trail:
            x = rec_scan(x, params["trail"])
        return rms_norm(x, params["final_norm"], cfg.norm_eps)

    def loss(self, params, batch):
        x = self._embed(params, batch["tokens"])
        positions = jnp.arange(x.shape[1])[None, :]
        h = self._backbone(params, x, positions, train=True)
        return chunked_lm_loss(h, self._head_weight(params), batch["labels"],
                               self.cfg.vocab,
                               unroll=not self.cfg.scan_layers)

    def prefill(self, params, batch):
        x = self._embed(params, batch["tokens"])
        positions = jnp.arange(x.shape[1])[None, :]
        h = self._backbone(params, x, positions, train=False)
        return jnp.einsum("bd,dv->bv", h[:, -1], self._head_weight(params))

    # ---- decode -------------------------------------------------------
    def decode_state_shapes(self, shape: ShapeConfig, multi_pod: bool):
        cfg = self.cfg
        B = shape.global_batch
        slots = min(cfg.hybrid.attn_window, shape.seq_len)
        n_rec = self.n_groups * cfg.hybrid.pattern_rec + self.n_trail
        bspec = batch_axes(B, multi_pod)
        kv = (self.n_groups, B, slots, cfg.n_kv_heads, cfg.hd)
        structs = DecodeState(
            pos=jax.ShapeDtypeStruct((), jnp.int32),
            kv_k=jax.ShapeDtypeStruct(kv, self.dtype),
            kv_v=jax.ShapeDtypeStruct(kv, self.dtype),
            kv_pos=jax.ShapeDtypeStruct((B, slots), jnp.int32),
            rec_h=jax.ShapeDtypeStruct((n_rec, B, self.lru), jnp.float32),
            rec_tail=jax.ShapeDtypeStruct((n_rec, B, 3, self.lru), self.dtype))
        kv_seq_axis = "model" if cfg.n_kv_heads == 1 else None
        specs = DecodeState(
            pos=P(), kv_k=P(None, bspec, kv_seq_axis, None, None),
            kv_v=P(None, bspec, kv_seq_axis, None, None),
            kv_pos=P(bspec, kv_seq_axis),
            rec_h=P(None, bspec, "model"),
            rec_tail=P(None, bspec, None, "model"))
        return structs, specs

    def init_decode_state(self, batch: int, max_len: int) -> DecodeState:
        cfg = self.cfg
        slots = min(cfg.hybrid.attn_window, max_len)
        n_rec = self.n_groups * cfg.hybrid.pattern_rec + self.n_trail
        z = lambda *s: jnp.zeros(s, self.dtype)
        return DecodeState(
            pos=jnp.zeros((), jnp.int32),
            kv_k=z(self.n_groups, batch, slots, cfg.n_kv_heads, cfg.hd),
            kv_v=z(self.n_groups, batch, slots, cfg.n_kv_heads, cfg.hd),
            kv_pos=jnp.full((batch, slots), -1, jnp.int32),
            rec_h=jnp.zeros((n_rec, batch, self.lru), jnp.float32),
            rec_tail=z(n_rec, batch, 3, self.lru))

    def decode_step(self, params, state: DecodeState, batch):
        cfg = self.cfg
        x = self._embed(params, batch["tokens"])
        pos = state.pos
        positions = jnp.full((x.shape[0], 1), pos, jnp.int32)
        pr = cfg.hybrid.pattern_rec
        n_grp_rec = self.n_groups * pr
        rec_h_g = state.rec_h[:n_grp_rec].reshape(self.n_groups, pr,
                                                  *state.rec_h.shape[1:])
        rec_t_g = state.rec_tail[:n_grp_rec].reshape(self.n_groups, pr,
                                                     *state.rec_tail.shape[1:])

        def gbody(xx, inp):
            gp, hs, tails, ck, cv = inp

            def rbody(c, rin):
                lp, h0, tl = rin
                c2, h_last, tl2 = self._rec_block(lp, c, h0=h0, tail=tl,
                                                  decode=True)
                return c2, (h_last, tl2)

            xx, (h_new, t_new) = scan_or_unroll(
                rbody, xx, (gp["rec"], hs, tails),
                unroll=not cfg.scan_layers)
            lp = gp["attn_blk"]
            h = rms_norm(xx, lp["norm1"], cfg.norm_eps)
            q, k, v = qkv(lp["attn"], h, cfg, positions)
            ck, cv, kvpos = attn.cache_write(ck, cv, state.kv_pos, k, v, pos)
            o = attn.attention_decode(q, ck, cv, kvpos, pos,
                                      window=cfg.hybrid.attn_window)
            xx = xx + jnp.einsum("bse,ed->bsd", o.reshape(o.shape[0], 1, -1),
                                 lp["attn"]["wo"])
            h2 = rms_norm(xx, lp["norm2"], cfg.norm_eps)
            xx = xx + mlp_mod.mlp_apply(lp["mlp"], h2, cfg.mlp_act)
            return xx, (h_new, t_new, ck, cv)

        x, (h_new, t_new, ck, cv) = scan_or_unroll(
            gbody, x, (params["groups"], rec_h_g, rec_t_g,
                       state.kv_k, state.kv_v), unroll=not cfg.scan_layers)

        trail_h, trail_t = (state.rec_h[n_grp_rec:], state.rec_tail[n_grp_rec:])
        if self.n_trail:
            def tbody(c, rin):
                lp, h0, tl = rin
                c2, h_last, tl2 = self._rec_block(lp, c, h0=h0, tail=tl,
                                                  decode=True)
                return c2, (h_last, tl2)
            x, (trail_h, trail_t) = scan_or_unroll(
                tbody, x, (params["trail"], trail_h, trail_t),
                unroll=not cfg.scan_layers)

        slots = state.kv_k.shape[2]
        slot = (pos % slots).astype(jnp.int32)
        kv_pos = jax.lax.dynamic_update_slice(
            state.kv_pos, jnp.full((x.shape[0], 1), pos, jnp.int32), (0, slot))
        rec_h = jnp.concatenate([h_new.reshape(n_grp_rec, *h_new.shape[2:]),
                                 trail_h], axis=0)
        rec_tail = jnp.concatenate([t_new.reshape(n_grp_rec, *t_new.shape[2:]),
                                    trail_t], axis=0)
        h = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = jnp.einsum("bd,dv->bv", h[:, -1], self._head_weight(params))
        return logits, dataclasses.replace(
            state, pos=pos + 1, kv_k=ck, kv_v=cv, kv_pos=kv_pos,
            rec_h=rec_h, rec_tail=rec_tail)


# ======================================================================
# Whisper-style encoder-decoder (audio frontend stubbed to frame embeddings)
# ======================================================================
class EncDecLM(LMBase):
    def _enc_layer_defs(self):
        cfg = self.cfg
        return {
            "norm1": ParamDef((cfg.d_model,), (None,), init=ones_init),
            "attn": attn_defs(cfg),
            "norm2": ParamDef((cfg.d_model,), (None,), init=ones_init),
            "mlp": mlp_mod.mlp_defs(cfg.d_model, cfg.d_ff, cfg.mlp_act),
        }

    def _dec_layer_defs(self):
        d = self._enc_layer_defs()
        d["norm_x"] = ParamDef((self.cfg.d_model,), (None,), init=ones_init)
        d["xattn"] = attn_defs(self.cfg)
        return d

    def param_defs(self):
        cfg = self.cfg
        out = self._embed_defs()
        out["enc_layers"] = stack_defs(self._enc_layer_defs(),
                                       cfg.n_encoder_layers)
        out["enc_norm"] = ParamDef((cfg.d_model,), (None,), init=ones_init)
        out["dec_layers"] = stack_defs(self._dec_layer_defs(), cfg.n_layers)
        return out

    def _encode(self, params, frames, *, train: bool):
        cfg = self.cfg
        positions = jnp.arange(frames.shape[1])[None, :]

        def body(xx, lp):
            xx = self.constrain(xx)
            h = rms_norm(xx, lp["norm1"], cfg.norm_eps)
            q, k, v = qkv(lp["attn"], h, cfg, positions)
            o = attn.attention_prefill(q, k, v, causal=False,
                                       unroll=not cfg.scan_layers)
            xx = xx + jnp.einsum("bse,ed->bsd",
                                 o.reshape(o.shape[0], o.shape[1], -1),
                                 lp["attn"]["wo"])
            h2 = rms_norm(xx, lp["norm2"], cfg.norm_eps)
            return xx + mlp_mod.mlp_apply(lp["mlp"], h2, cfg.mlp_act), None

        fn = remat_wrap(body, cfg.remat if train else "nothing")
        x, _ = scan_or_unroll(fn, frames, params["enc_layers"],
                              unroll=not cfg.scan_layers)
        return rms_norm(x, params["enc_norm"], cfg.norm_eps)

    def _dec_block(self, lp, x, enc, positions):
        cfg = self.cfg
        x = self.constrain(x)
        h = rms_norm(x, lp["norm1"], cfg.norm_eps)
        q, k, v = qkv(lp["attn"], h, cfg, positions)
        o = attn.attention_prefill(q, k, v, causal=True,
                                   unroll=not cfg.scan_layers)
        x = x + jnp.einsum("bse,ed->bsd",
                           o.reshape(o.shape[0], o.shape[1], -1),
                           lp["attn"]["wo"])
        hx = rms_norm(x, lp["norm_x"], cfg.norm_eps)
        enc_pos = jnp.arange(enc.shape[1])[None, :]
        qx, _, _ = qkv(lp["xattn"], hx, cfg,
                       jnp.zeros((x.shape[0], x.shape[1]), jnp.int32))
        kx = jnp.einsum("bsd,de->bse", enc, lp["xattn"]["wk"])
        vx = jnp.einsum("bsd,de->bse", enc, lp["xattn"]["wv"])
        if cfg.qkv_bias:
            kx, vx = kx + lp["xattn"]["bk"], vx + lp["xattn"]["bv"]
        B, F = enc.shape[0], enc.shape[1]
        kx = attn.apply_rope(kx.reshape(B, F, cfg.n_kv_heads, cfg.hd), enc_pos,
                             cfg.rope_theta)
        vx = vx.reshape(B, F, cfg.n_kv_heads, cfg.hd)
        ox = attn.attention_prefill(qx, kx, vx, causal=False,
                                    unroll=not cfg.scan_layers)
        x = x + jnp.einsum("bse,ed->bsd",
                           ox.reshape(ox.shape[0], ox.shape[1], -1),
                           lp["xattn"]["wo"])
        h2 = rms_norm(x, lp["norm2"], cfg.norm_eps)
        return x + mlp_mod.mlp_apply(lp["mlp"], h2, cfg.mlp_act)

    def _decode_stack(self, params, x, enc, positions, *, train: bool):
        cfg = self.cfg

        def body(xx, lp):
            return self._dec_block(lp, xx, enc, positions), None

        fn = remat_wrap(body, cfg.remat if train else "nothing")
        x, _ = scan_or_unroll(fn, x, params["dec_layers"],
                              unroll=not cfg.scan_layers)
        return rms_norm(x, params["final_norm"], cfg.norm_eps)

    def loss(self, params, batch):
        cfg = self.cfg
        enc = self._encode(params, batch["frames"], train=True)
        x = self._embed(params, batch["tokens"])
        positions = jnp.arange(x.shape[1])[None, :]
        h = self._decode_stack(params, x, enc, positions, train=True)
        return chunked_lm_loss(h, self._head_weight(params), batch["labels"],
                               cfg.vocab, unroll=not cfg.scan_layers)

    def prefill(self, params, batch):
        enc = self._encode(params, batch["frames"], train=False)
        x = self._embed(params, batch["tokens"])
        positions = jnp.arange(x.shape[1])[None, :]
        h = self._decode_stack(params, x, enc, positions, train=False)
        return jnp.einsum("bd,dv->bv", h[:, -1], self._head_weight(params))

    # ---- decode -------------------------------------------------------
    def decode_state_shapes(self, shape: ShapeConfig, multi_pod: bool):
        cfg = self.cfg
        B = shape.global_batch
        bspec = batch_axes(B, multi_pod)
        kv = (cfg.n_layers, B, shape.seq_len, cfg.n_kv_heads, cfg.hd)
        xkv = (cfg.n_layers, B, cfg.encoder_len, cfg.n_kv_heads, cfg.hd)
        structs = DecodeState(
            pos=jax.ShapeDtypeStruct((), jnp.int32),
            kv_k=jax.ShapeDtypeStruct(kv, self.dtype),
            kv_v=jax.ShapeDtypeStruct(kv, self.dtype),
            kv_pos=jax.ShapeDtypeStruct((B, shape.seq_len), jnp.int32),
            cross_k=jax.ShapeDtypeStruct(xkv, self.dtype),
            cross_v=jax.ShapeDtypeStruct(xkv, self.dtype))
        specs = DecodeState(
            pos=P(), kv_k=P(None, bspec, "model", None, None),
            kv_v=P(None, bspec, "model", None, None),
            kv_pos=P(bspec, "model"),
            cross_k=P(None, bspec, None, None, None),
            cross_v=P(None, bspec, None, None, None))
        return structs, specs

    def init_decode_state(self, batch: int, max_len: int) -> DecodeState:
        cfg = self.cfg
        z = lambda *s: jnp.zeros(s, self.dtype)
        return DecodeState(
            pos=jnp.zeros((), jnp.int32),
            kv_k=z(cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.hd),
            kv_v=z(cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.hd),
            kv_pos=jnp.full((batch, max_len), -1, jnp.int32),
            cross_k=z(cfg.n_layers, batch, cfg.encoder_len, cfg.n_kv_heads,
                      cfg.hd),
            cross_v=z(cfg.n_layers, batch, cfg.encoder_len, cfg.n_kv_heads,
                      cfg.hd))

    def decode_step(self, params, state: DecodeState, batch):
        cfg = self.cfg
        x = self._embed(params, batch["tokens"])
        pos = state.pos
        positions = jnp.full((x.shape[0], 1), pos, jnp.int32)

        def body(xx, inp):
            lp, ck, cv, xk, xv = inp
            h = rms_norm(xx, lp["norm1"], cfg.norm_eps)
            q, k, v = qkv(lp["attn"], h, cfg, positions)
            ck, cv, kvpos = attn.cache_write(ck, cv, state.kv_pos, k, v, pos)
            o = attn.attention_decode(q, ck, cv, kvpos, pos)
            xx = xx + jnp.einsum("bse,ed->bsd", o.reshape(o.shape[0], 1, -1),
                                 lp["attn"]["wo"])
            hx = rms_norm(xx, lp["norm_x"], cfg.norm_eps)
            qx, _, _ = qkv(lp["xattn"], hx, cfg,
                           jnp.zeros((xx.shape[0], 1), jnp.int32))
            xpos = jnp.broadcast_to(jnp.arange(xk.shape[1]),
                                    (xx.shape[0], xk.shape[1]))
            ox = attn.attention_decode(qx, xk, xv, xpos,
                                       jnp.int32(xk.shape[1]))
            xx = xx + jnp.einsum("bse,ed->bsd", ox.reshape(ox.shape[0], 1, -1),
                                 lp["xattn"]["wo"])
            h2 = rms_norm(xx, lp["norm2"], cfg.norm_eps)
            return xx + mlp_mod.mlp_apply(lp["mlp"], h2, cfg.mlp_act), (ck, cv)

        x, (ck, cv) = scan_or_unroll(
            body, x, (params["dec_layers"], state.kv_k, state.kv_v,
                      state.cross_k, state.cross_v),
            unroll=not cfg.scan_layers)
        slots = state.kv_k.shape[2]
        slot = (pos % slots).astype(jnp.int32)
        kv_pos = jax.lax.dynamic_update_slice(
            state.kv_pos, jnp.full((x.shape[0], 1), pos, jnp.int32), (0, slot))
        h = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = jnp.einsum("bd,dv->bv", h[:, -1], self._head_weight(params))
        return logits, dataclasses.replace(state, pos=pos + 1, kv_k=ck,
                                           kv_v=cv, kv_pos=kv_pos)


# ======================================================================
def build_model(cfg: ModelConfig) -> LMBase:
    family = {
        "dense": DenseLM, "moe": DenseLM, "vlm": DenseLM,
        "ssm": SSMLM, "hybrid": HybridLM, "encdec": EncDecLM,
    }[cfg.family]
    return family(cfg)
