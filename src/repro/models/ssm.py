"""Mamba-2 block — chunked State-Space Duality (SSD), faithful to
arXiv:2405.21060: within-chunk quadratic form + inter-chunk linear recurrence.

Shapes (per layer): d_inner = expand * d_model, H heads of dim P, state N.
The in-projection produces (z, x, B, C, dt); (x, B, C) pass through a causal
depthwise conv of width 4; the SSD scan uses per-head scalar decay
``A = -exp(a_log)``. Decode keeps an O(1) state: [B, H, P, N] + conv tail —
which is why mamba2 runs the 524k-decode shape that dense attention cannot.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import (ParamDef, ones_init, rms_norm,
                                 scan_or_unroll, zeros_init)
from repro.models.config import SSMConfig


def ssm_defs(d_model: int, ssm: SSMConfig) -> Dict[str, ParamDef]:
    d_inner = ssm.expand * d_model
    H = ssm.n_heads(d_model)
    N = ssm.d_state
    conv_dim = d_inner + 2 * N
    d_in = 2 * d_inner + 2 * N + H
    return {
        "in_proj": ParamDef((d_model, d_in), ("fsdp", "tp")),
        "conv_w": ParamDef((ssm.conv_width, conv_dim), (None, "tp")),
        "conv_b": ParamDef((conv_dim,), ("tp",), init=zeros_init),
        "a_log": ParamDef((H,), (None,), init=ones_init),
        "dt_bias": ParamDef((H,), (None,), init=zeros_init),
        "d_skip": ParamDef((H,), (None,), init=ones_init),
        "norm_g": ParamDef((d_inner,), ("tp",), init=ones_init),
        "out_proj": ParamDef((d_inner, d_model), ("tp", "fsdp")),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 tail: jax.Array | None = None) -> jax.Array:
    """Depthwise causal conv, width W: x [B, S, C], w [W, C].

    ``tail``: previous W-1 inputs for decode continuation [B, W-1, C].
    """
    W = w.shape[0]
    if tail is None:
        tail = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([tail, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(W))
    return jax.nn.silu((out + b).astype(jnp.float32)).astype(x.dtype)


def _split_proj(h: jax.Array, d_inner: int, N: int, H: int):
    z, xBC, dt = jnp.split(h, [d_inner, d_inner + d_inner + 2 * N], axis=-1)
    return z, xBC, dt


def ssd_chunked(x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
                Cm: jax.Array, chunk: int,
                h0: jax.Array | None = None, *, unroll: bool = False
                ) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD scan.

    x: [B, S, H, P]; dt: [B, S, H] (>=0); A: [H] (<0);
    Bm, Cm: [B, S, N] (single group). Returns (y [B, S, H, P], h_last
    [B, H, P, N]).
    """
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0, "sequence must divide the SSD chunk"
    nc = S // Q
    xf = x.astype(jnp.float32).reshape(Bsz, nc, Q, H, P)
    dtf = dt.astype(jnp.float32).reshape(Bsz, nc, Q, H)
    Bf = Bm.astype(jnp.float32).reshape(Bsz, nc, Q, N)
    Cf = Cm.astype(jnp.float32).reshape(Bsz, nc, Q, N)

    dA = dtf * A                                   # [B, nc, Q, H] (log decay)
    cum = jnp.cumsum(dA, axis=2)                   # inclusive within chunk

    # --- within-chunk (quadratic) term ---------------------------------
    # G[i,j] = (C_i . B_j) * exp(cum_i - cum_j) * dt_j  for i >= j
    CB = jnp.einsum("bcin,bcjn->bcij", Cf, Bf)     # [B, nc, Q, Q]
    li = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # [B,nc,Q,Q,H]
    causal = jnp.tril(jnp.ones((Q, Q), bool))
    decay = jnp.exp(jnp.where(causal[None, None, :, :, None], li, -jnp.inf))
    G = CB[..., None] * decay * dtf[:, :, None, :, :]    # [B,nc,i,j,H]
    y_diag = jnp.einsum("bcijh,bcjhp->bcihp", G, xf)

    # --- chunk-end states ----------------------------------------------
    # h_end_c = sum_j exp(cum_Q - cum_j) * dt_j * x_j B_j^T  (+ carry)
    dec_end = jnp.exp(cum[:, :, -1:, :] - cum)            # [B, nc, Q, H]
    states = jnp.einsum("bcjh,bcjhp,bcjn->bchpn",
                        dec_end * dtf, xf, Bf)            # [B, nc, H, P, N]
    chunk_decay = jnp.exp(cum[:, :, -1, :])               # [B, nc, H]

    def carry_body(h, inp):
        st, cd = inp                                      # [B,H,P,N], [B,H]
        h_new = h * cd[..., None, None] + st
        return h_new, h

    h_init = (jnp.zeros((Bsz, H, P, N), jnp.float32) if h0 is None
              else h0.astype(jnp.float32))
    h_last, h_starts = scan_or_unroll(
        carry_body, h_init,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
        unroll=unroll)
    h_starts = jnp.moveaxis(h_starts, 0, 1)               # [B, nc, H, P, N]

    # --- inter-chunk contribution: C_i . (exp(cum_i) * h_start) ---------
    y_off = jnp.einsum("bcin,bcihpn->bcihp",
                       Cf, jnp.exp(cum)[..., None, None]
                       * h_starts[:, :, None])
    y = (y_diag + y_off).reshape(Bsz, S, H, P)
    return y.astype(x.dtype), h_last


def ssm_apply(params, x: jax.Array, ssm: SSMConfig,
              state: jax.Array | None = None,
              conv_tail: jax.Array | None = None, *, decode: bool = False,
              unroll: bool = False):
    """Full Mamba-2 mixer. Returns (y, new_state, new_conv_tail)."""
    B, S, d_model = x.shape
    d_inner = ssm.expand * d_model
    H, N, P = ssm.n_heads(d_model), ssm.d_state, ssm.head_dim

    h = jnp.einsum("bsd,de->bse", x, params["in_proj"])
    z, xBC, dt_raw = _split_proj(h, d_inner, N, H)
    new_tail = None
    if decode:
        xBC_in = xBC
        xBC = _causal_conv(xBC, params["conv_w"], params["conv_b"], conv_tail)
        new_tail = jnp.concatenate([conv_tail, xBC_in], axis=1)[:, 1:]
    else:
        xBC = _causal_conv(xBC, params["conv_w"], params["conv_b"])
    xs, Bm, Cm = jnp.split(xBC, [d_inner, d_inner + N], axis=-1)
    xs = xs.reshape(B, S, H, P)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(params["a_log"].astype(jnp.float32))

    if decode:
        # O(1) state update: h' = exp(dt A) h + dt x B^T ; y = h' C + D x.
        assert S == 1 and state is not None
        dec = jnp.exp(dt[:, 0] * A)                       # [B, H]
        upd = jnp.einsum("bh,bhp,bn->bhpn", dt[:, 0],
                         xs[:, 0].astype(jnp.float32),
                         Bm[:, 0].astype(jnp.float32))
        h_new = state * dec[..., None, None] + upd
        y = jnp.einsum("bhpn,bn->bhp", h_new,
                       Cm[:, 0].astype(jnp.float32))[:, None]
        new_state = h_new
    else:
        y, new_state = ssd_chunked(xs, dt, A, Bm, Cm, ssm.chunk, h0=state,
                                   unroll=unroll)

    y = y + params["d_skip"].astype(jnp.float32)[:, None] * xs.astype(jnp.float32)
    y = y.reshape(B, S, d_inner).astype(x.dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    y = rms_norm(y, params["norm_g"])
    out = jnp.einsum("bse,ed->bsd", y, params["out_proj"])
    return out, new_state, new_tail
