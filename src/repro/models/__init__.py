from repro.models.config import (HybridConfig, LM_SHAPES, ModelConfig,
                                 MoEConfig, SSMConfig, ShapeConfig,
                                 shapes_for, skipped_shapes_for)
from repro.models.lm import (DecodeState, DenseLM, EncDecLM, HybridLM, LMBase,
                             SSMLM, build_model)

__all__ = ["ModelConfig", "MoEConfig", "SSMConfig", "HybridConfig",
           "ShapeConfig", "LM_SHAPES", "shapes_for", "skipped_shapes_for",
           "build_model", "LMBase", "DenseLM", "SSMLM", "HybridLM",
           "EncDecLM", "DecodeState"]
