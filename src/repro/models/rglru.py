"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Real-Gated Linear Recurrent Unit:

    r_t = sigmoid(W_r u_t + b_r)            (recurrence gate)
    i_t = sigmoid(W_i u_t + b_i)            (input gate)
    a_t = exp(-c * softplus(L) * r_t)       (c = 8, L learnable)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * u_t)

The recurrence is elementwise-diagonal, so prefill/train parallelises with a
chunked associative scan (log-depth within a chunk, sequential carry across
chunks — bounded memory at 32k/524k). The gate projections use 16-block
block-diagonal weights as in the published model. The recurrent block wraps
the RG-LRU in the Griffin layout: (gate branch: linear+GeLU) * (conv1d +
RG-LRU branch), then a linear out-projection.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ParamDef, ones_init, zeros_init

RG_LRU_C = 8.0
N_GATE_BLOCKS = 16


def rglru_defs(d_model: int, lru_width: int) -> Dict[str, ParamDef]:
    blk = lru_width // N_GATE_BLOCKS
    return {
        "w_gate": ParamDef((d_model, lru_width), ("fsdp", "tp")),
        "w_branch": ParamDef((d_model, lru_width), ("fsdp", "tp")),
        "conv_w": ParamDef((4, lru_width), (None, "tp")),
        "conv_b": ParamDef((lru_width,), ("tp",), init=zeros_init),
        "w_r": ParamDef((N_GATE_BLOCKS, blk, blk), (None, None, "tp")),
        "b_r": ParamDef((lru_width,), ("tp",), init=zeros_init),
        "w_i": ParamDef((N_GATE_BLOCKS, blk, blk), (None, None, "tp")),
        "b_i": ParamDef((lru_width,), ("tp",), init=zeros_init),
        "lam": ParamDef((lru_width,), ("tp",), init=ones_init),
        "w_out": ParamDef((lru_width, d_model), ("tp", "fsdp")),
    }


def _block_diag(u: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """u: [B, S, lru]; w: [nb, blk, blk] block-diagonal projection."""
    B, S, L = u.shape
    nb, blk, _ = w.shape
    ub = u.reshape(B, S, nb, blk)
    out = jnp.einsum("bsnk,nkj->bsnj", ub, w).reshape(B, S, L)
    return out + b


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 tail: jax.Array | None = None) -> jax.Array:
    W = w.shape[0]
    if tail is None:
        tail = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([tail, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(W))
    return out + b


def rglru_scan(u: jax.Array, a: jax.Array, h0: jax.Array | None,
               chunk: int = 2048) -> Tuple[jax.Array, jax.Array]:
    """h_t = a_t h_{t-1} + sqrt(1-a_t^2) i u_t (the gated input is prefolded).

    u: [B, S, L] gated inputs; a: [B, S, L] decay in (0,1).
    Chunked associative scan; returns (h [B,S,L], h_last [B,L]).
    """
    B, S, L = u.shape
    Q = min(chunk, S)
    assert S % Q == 0
    nc = S // Q
    af = a.astype(jnp.float32).reshape(B, nc, Q, L)
    uf = u.astype(jnp.float32).reshape(B, nc, Q, L)

    def chunk_body(h, inp):
        ac, uc = inp                               # [B, Q, L]

        def op(x, y):
            a1, b1 = x
            a2, b2 = y
            return a1 * a2, a2 * b1 + b2

        aa, hh = jax.lax.associative_scan(op, (ac, uc), axis=1)
        hh = hh + aa * h[:, None]                  # fold in the carry
        return hh[:, -1], hh

    h_init = (jnp.zeros((B, L), jnp.float32) if h0 is None
              else h0.astype(jnp.float32))
    h_last, hs = jax.lax.scan(chunk_body, h_init,
                              (jnp.moveaxis(af, 1, 0), jnp.moveaxis(uf, 1, 0)))
    h = jnp.moveaxis(hs, 0, 1).reshape(B, S, L)
    return h.astype(u.dtype), h_last


def rglru_block_apply(params, x: jax.Array,
                      h0: jax.Array | None = None,
                      conv_tail: jax.Array | None = None, *,
                      decode: bool = False):
    """Griffin recurrent block. Returns (y, h_last, new_conv_tail)."""
    gate = jax.nn.gelu(jnp.einsum("bsd,dl->bsl", x, params["w_gate"])
                       .astype(jnp.float32)).astype(x.dtype)
    u_in = jnp.einsum("bsd,dl->bsl", x, params["w_branch"])
    new_tail = None
    if decode:
        u = _causal_conv(u_in, params["conv_w"], params["conv_b"], conv_tail)
        new_tail = jnp.concatenate([conv_tail, u_in], axis=1)[:, 1:]
    else:
        u = _causal_conv(u_in, params["conv_w"], params["conv_b"])

    r = jax.nn.sigmoid(_block_diag(u, params["w_r"], params["b_r"])
                       .astype(jnp.float32))
    i = jax.nn.sigmoid(_block_diag(u, params["w_i"], params["b_i"])
                       .astype(jnp.float32))
    log_a = -RG_LRU_C * jax.nn.softplus(params["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    gated = (jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12))
             * i * u.astype(jnp.float32)).astype(x.dtype)

    if decode:
        assert x.shape[1] == 1 and h0 is not None
        h_new = (h0.astype(jnp.float32) * a[:, 0]
                 + gated[:, 0].astype(jnp.float32))
        h = h_new[:, None].astype(x.dtype)
        h_last = h_new
    else:
        h, h_last = rglru_scan(gated, a.astype(jnp.float32), h0)

    y = h * gate
    return jnp.einsum("bsl,ld->bsd", y, params["w_out"]), h_last, new_tail
