"""Feed-forward blocks: SwiGLU / GeGLU / GELU."""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.models.common import ParamDef


def mlp_defs(d_model: int, d_ff: int, act: str) -> Dict[str, ParamDef]:
    """Gated variants fuse gate+up into one projection for a single GEMM."""
    if act in ("swiglu", "geglu"):
        return {
            "w_in": ParamDef((d_model, 2 * d_ff), ("fsdp", "tp")),
            "w_out": ParamDef((d_ff, d_model), ("tp", "fsdp")),
        }
    return {
        "w_in": ParamDef((d_model, d_ff), ("fsdp", "tp")),
        "w_out": ParamDef((d_ff, d_model), ("tp", "fsdp")),
    }


def mlp_apply(params, x: jax.Array, act: str) -> jax.Array:
    h = jnp.einsum("...d,df->...f", x, params["w_in"])
    if act in ("swiglu", "geglu"):
        gate, up = jnp.split(h, 2, axis=-1)
        g = jax.nn.silu(gate.astype(jnp.float32)) if act == "swiglu" \
            else jax.nn.gelu(gate.astype(jnp.float32))
        h = (g * up.astype(jnp.float32)).astype(x.dtype)
    else:
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("...f,fd->...d", h, params["w_out"])
