"""Shared model primitives: norms, rope, initialisers, partition specs."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

Params = Any  # nested dict pytree of jnp arrays


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[name]


# ----------------------------------------------------------------------
# Initialisers — all params are created through `make_param` so that the
# partition-spec tree can be built from the same declarative tables.
# ----------------------------------------------------------------------
def normal_init(key: jax.Array, shape: Sequence[int], dtype, scale: float):
    fan_in = shape[0] if len(shape) > 1 else 1
    std = scale / max(1.0, fan_in) ** 0.5
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def zeros_init(key: jax.Array, shape: Sequence[int], dtype, scale: float = 0.0):
    del key, scale
    return jnp.zeros(shape, dtype)


def ones_init(key: jax.Array, shape: Sequence[int], dtype, scale: float = 0.0):
    del key, scale
    return jnp.ones(shape, dtype)


@dataclasses.dataclass(frozen=True)
class ParamDef:
    """Declarative parameter definition: shape + logical sharding + init."""
    shape: Tuple[int, ...]
    spec: Tuple[Optional[str], ...]          # logical axes, see LOGICAL_RULES
    init: Callable = normal_init
    scale: float = 1.0


# Logical-axis -> mesh-axis rules. `fsdp` shards the d_model/storage dim over
# the data axis (ZeRO-3 style weight sharding); `tp` shards output features
# over the model axis (Megatron style). Batch goes over (pod, data).
LOGICAL_RULES: Dict[str, Optional[Any]] = {
    "fsdp": "data",
    "tp": "model",
    "layers": None,
    "experts": None,
    "batch": ("pod", "data"),
    "batch_1pod": "data",
    None: None,
}


def logical_to_spec(axes: Sequence[Optional[str]], *, multi_pod: bool,
                    rules: Optional[Dict[str, Any]] = None) -> P:
    rules = dict(LOGICAL_RULES if rules is None else rules)
    if not multi_pod:
        rules["batch"] = "data"
    out = []
    for a in axes:
        m = rules.get(a, None) if a is not None else None
        out.append(m)
    return P(*out)


def init_params(defs: Dict[str, Any], key: jax.Array, dtype) -> Params:
    """Materialise a (possibly nested) dict of ParamDefs."""
    flat, treedef = jax.tree.flatten(defs, is_leaf=lambda x: isinstance(x, ParamDef))
    keys = jax.random.split(key, len(flat))
    leaves = [d.init(k, d.shape, dtype, d.scale) for d, k in zip(flat, keys)]
    return jax.tree.unflatten(treedef, leaves)


def spec_tree(defs: Dict[str, Any], *, multi_pod: bool) -> Params:
    return jax.tree.map(
        lambda d: logical_to_spec(d.spec, multi_pod=multi_pod),
        defs, is_leaf=lambda x: isinstance(x, ParamDef))


def shape_tree(defs: Dict[str, Any], dtype) -> Params:
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, dtype),
        defs, is_leaf=lambda x: isinstance(x, ParamDef))


# ----------------------------------------------------------------------
# scan-or-unroll: one knob for every sequential loop in the model zoo
# ----------------------------------------------------------------------
def scan_or_unroll(fn, carry, xs, unroll: bool, length: Optional[int] = None):
    """``jax.lax.scan`` or an unrolled python loop over the leading axis.

    Unrolled mode exists for two reasons: (i) XLA pipelines collectives
    across unrolled bodies (a §Perf lever), and (ii) ``cost_analysis``
    counts a while-loop body ONCE regardless of trip count, so the roofline
    validation harness compiles small fully-unrolled configs to get exact
    FLOP/byte counts (see launch/costfit.py)."""
    if not unroll:
        return jax.lax.scan(fn, carry, xs, length=length)
    n = length if xs is None else jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(n):
        x_i = None if xs is None else jax.tree.map(lambda p: p[i], xs)
        carry, y = fn(carry, x_i)
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree.map(lambda *zs: jnp.stack(zs), *ys)
    else:
        ys = None
    return carry, ys


# ----------------------------------------------------------------------
# Norms
# ----------------------------------------------------------------------
def rms_norm(x: jax.Array, gamma: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    scale = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (xf * scale).astype(x.dtype) * gamma


# ----------------------------------------------------------------------
# Rotary position embeddings
# ----------------------------------------------------------------------
def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., seq, heads, head_dim]; positions: [..., seq]."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                       # [hd/2]
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # [..., S, hd/2]
    angles = angles[..., :, None, :]                          # [..., S, 1, hd/2]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------------
# Losses
# ----------------------------------------------------------------------
def softmax_xent(logits: jax.Array, labels: jax.Array,
                 true_vocab: int) -> jax.Array:
    """Cross-entropy in f32 with padded-vocab masking; mean over tokens."""
    logits = logits.astype(jnp.float32)
    if logits.shape[-1] > true_vocab:
        neg = jnp.finfo(jnp.float32).min
        mask = jnp.arange(logits.shape[-1]) < true_vocab
        logits = jnp.where(mask, logits, neg)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)
