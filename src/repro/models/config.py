"""Model/architecture configuration schema for the assigned architecture pool."""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

VOCAB_PAD_MULTIPLE = 2048   # pad vocab so 16-way shards stay 128-lane aligned


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25
    # "dense" = Mesh-TF one-hot-matmul dispatch (faithful baseline);
    # "gather" = indexed scatter/gather (§Perf iteration "moe-gather");
    # "sharded" = mesh expert parallelism (must run inside a shard_map —
    # see models.moe.moe_forward_sharded); any other value names a
    # repro.fabric backend ("reference", "pallas", ...) — the layer then
    # routes groups through Fabric.transfer, sharing the shell's
    # interconnect implementation.
    dispatch: str = "dense"
    # Kernel-lowering seam for the fabric-backed dispatch impls
    # (repro.fabric.KernelMode aliases: "auto" | "xla" | "pallas" |
    # "pallas_interpret").  Resolved once when the geometry's fabric is
    # built; ignored by "dense"/"gather".  See docs/training.md.
    kernel_mode: str = "auto"


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 SSD block parameters."""
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk: int = 256

    def n_heads(self, d_model: int) -> int:
        return self.expand * d_model // self.head_dim


@dataclasses.dataclass(frozen=True)
class HybridConfig:
    """RecurrentGemma block pattern: `pattern_rec` recurrent blocks followed by
    one local-attention block (1:2 attention:recurrence ratio)."""
    pattern_rec: int = 2
    lru_width: Optional[int] = None
    attn_window: int = 2048


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    qkv_bias: bool = False
    attn_window: Optional[int] = None        # SWA window (None = full attention)
    tied_embeddings: bool = False
    rope_theta: float = 10_000.0
    mlp_act: str = "swiglu"                  # swiglu | geglu | gelu
    norm_eps: float = 1e-5
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid: Optional[HybridConfig] = None
    n_encoder_layers: int = 0                # enc-dec (whisper): encoder depth
    encoder_len: int = 1500                  # whisper frame count (stubbed)
    n_vision_patches: int = 0                # vlm stub patch count
    dtype: str = "bfloat16"
    # ------------------------------------------------------------------
    remat: str = "dots"                      # nothing | dots | full
    scan_layers: bool = True
    # K/V projection sharding. "tp" shards the Kv*hd dim over the model
    # axis — but with Kv < mesh_model (GQA kv=1..8 vs 16-way TP) that
    # fragments heads across devices and the partitioner inserts resharding
    # around every attention. "replicate" keeps K/V projections replicated
    # over the model axis (they are (d * Kv * hd) — tiny next to wq/wo) so
    # each device holds whole kv heads (§Perf iteration "kv-replicate").
    kv_shard: str = "tp"

    @property
    def hd(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        return self.d_model // max(1, self.n_heads)

    @property
    def vocab_padded(self) -> int:
        return math.ceil(self.vocab / VOCAB_PAD_MULTIPLE) * VOCAB_PAD_MULTIPLE

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch decode with a bounded-size attention state?"""
        return (self.family in ("ssm", "hybrid")
                or self.attn_window is not None)

    def validate(self) -> None:
        if self.family == "moe":
            assert self.moe is not None
        if self.family == "ssm":
            assert self.ssm is not None
        if self.family == "hybrid":
            assert self.hybrid is not None
        if self.family == "encdec":
            assert self.n_encoder_layers > 0
        if self.n_heads and self.n_kv_heads:
            assert self.n_heads % self.n_kv_heads == 0, "GQA group size"


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One (input-shape) cell: what the dry-run lowers."""
    name: str                      # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


LM_SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4_096, 256, "train"),
    ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    ShapeConfig("decode_32k", 32_768, 128, "decode"),
    ShapeConfig("long_500k", 524_288, 1, "decode"),
)


def shapes_for(cfg: ModelConfig) -> Tuple[ShapeConfig, ...]:
    """The assigned shape set, with the mandated skips applied.

    ``long_500k`` requires sub-quadratic attention; pure full-attention archs
    skip it (recorded in the roofline table as a skip, per DESIGN.md §5).
    """
    out = []
    for s in LM_SHAPES:
        if s.name == "long_500k" and not cfg.sub_quadratic:
            continue
        out.append(s)
    return tuple(out)


def skipped_shapes_for(cfg: ModelConfig) -> Tuple[ShapeConfig, ...]:
    return tuple(s for s in LM_SHAPES if s not in shapes_for(cfg))
