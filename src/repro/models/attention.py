"""GQA attention: chunked (flash-style) prefill/train path, cached decode path.

The prefill path is a pure-jnp online-softmax attention, double-scanned over
query and key/value chunks so (i) the HLO stays small (one chunk body compiled
once), (ii) peak memory is O(q_chunk x kv_chunk), never O(S^2) — which is what
lets 32k prefill lower on a 16 GB chip, and (iii) sliding-window attention
iterates only the banded kv chunks, making SWA prefill genuinely
sub-quadratic rather than masked-quadratic.

The Pallas flash kernel (``repro.kernels.flash_attention``) implements the
same contract for the TPU deploy path; this module is the XLA fallback used
by the CPU dry-run and the kernel's oracle.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import apply_rope  # re-export for layer code
from repro.models.common import scan_or_unroll

NEG_INF = -1e30


def _gqa_scores(q: jax.Array, k: jax.Array) -> jax.Array:
    """q: [B, Sq, K, G, D]; k: [B, Sk, K, D] -> scores [B, K, G, Sq, Sk]."""
    return jnp.einsum("bqkgd,bskd->bkgqs", q.astype(jnp.float32),
                      k.astype(jnp.float32))


def _gqa_out(p: jax.Array, v: jax.Array) -> jax.Array:
    """p: [B, K, G, Sq, Sk]; v: [B, Sk, K, D] -> [B, K, G, Sq, D]."""
    return jnp.einsum("bkgqs,bskd->bkgqd", p, v.astype(jnp.float32))


def attention_prefill(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool = True, window: Optional[int] = None,
                      q_chunk: int = 512, kv_chunk: int = 1024,
                      q_offset: int = 0, unroll: bool = False) -> jax.Array:
    """Chunked online-softmax attention.

    q: [B, Sq, H, D]; k, v: [B, Sk, Kv, D] with H = Kv * G (GQA).
    ``window``: sliding-window size (attend to keys in (pos-window, pos]).
    ``q_offset``: absolute position of q[0] relative to k[0] (cross-chunk
    prefill continuation). Returns [B, Sq, H, D] in q.dtype.
    """
    B, Sq, H, D = q.shape
    _, Sk, Kv, _ = k.shape
    G = H // Kv
    scale = D ** -0.5
    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Sk)
    # Pad sequence dims to chunk multiples.
    pq = (-Sq) % q_chunk
    pk = (-Sk) % kv_chunk
    qp = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0))) if pq else q
    kp = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0))) if pk else k
    vp = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0))) if pk else v
    nq, nk = qp.shape[1] // q_chunk, kp.shape[1] // kv_chunk
    qp = qp.reshape(B, nq, q_chunk, Kv, G, D) * scale
    kp = kp.reshape(B, nk, kv_chunk, Kv, D)
    vp = vp.reshape(B, nk, kv_chunk, Kv, D)

    kv_per_q = nk
    banded = window is not None and causal
    if banded:
        # A q chunk only sees kv chunks covering (q_start - window, q_end].
        kv_per_q = min(nk, (window + q_chunk) // kv_chunk + 2)

    def q_body(_, qi):
        qc = jnp.take(qp, qi, axis=1)                    # [B, qc, Kv, G, D]
        q_pos = q_offset + qi * q_chunk + jnp.arange(q_chunk)

        def kv_body(carry, kj_raw):
            m, l, acc = carry
            chunk_ok = (kj_raw >= 0) & (kj_raw < nk)     # guard band overrun
            kj = jnp.clip(kj_raw, 0, nk - 1)
            kc = jnp.take(kp, kj, axis=1)                # [B, kc, Kv, D]
            vc = jnp.take(vp, kj, axis=1)
            k_pos = kj * kv_chunk + jnp.arange(kv_chunk)
            s = _gqa_scores(qc, kc)                      # [B,Kv,G,qc,kc]
            mask = k_pos[None, :] <= (q_pos[:, None] if causal
                                      else jnp.full_like(q_pos[:, None],
                                                         jnp.iinfo(jnp.int32).max))
            if window is not None:
                mask &= k_pos[None, :] > (q_pos[:, None] - window)
            mask &= k_pos[None, :] < Sk                  # kv padding
            mask &= chunk_ok
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + _gqa_out(p, vc)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, Kv, G, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Kv, G, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, Kv, G, q_chunk, D), jnp.float32)
        if banded:
            first = (q_pos[0] - (window - 1)) // kv_chunk
            kjs = jnp.maximum(first, 0) + jnp.arange(kv_per_q)
        else:
            kjs = jnp.arange(kv_per_q)
        (m, l, acc), _ = scan_or_unroll(kv_body, (m0, l0, a0), kjs,
                                        unroll=unroll)
        out = acc / jnp.maximum(l, 1e-30)[..., None]     # [B,Kv,G,qc,D]
        return None, out.astype(q.dtype)

    _, outs = scan_or_unroll(q_body, None, jnp.arange(nq),
                             unroll=unroll)               # [nq,B,Kv,G,qc,D]
    out = jnp.transpose(outs, (1, 0, 4, 2, 3, 5))          # [B,nq,qc,Kv,G,D]
    out = out.reshape(B, nq * q_chunk, Kv * G, D)
    return out[:, :Sq]


# ----------------------------------------------------------------------
# KV cache + decode
# ----------------------------------------------------------------------
@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class KVCache:
    """Per-layer-stacked KV cache. ``positions`` holds the absolute position
    stored in each slot (-1 = empty); sliding-window archs use a ring buffer
    of ``window`` slots, so the 524k-decode cache stays bounded."""

    k: jax.Array           # [L, B, S, Kv, D]  (post-rope keys)
    v: jax.Array           # [L, B, S, Kv, D]
    positions: jax.Array   # [B, S] int32
    length: jax.Array      # [] int32 — number of tokens absorbed so far


def init_cache(n_layers: int, batch: int, max_len: int, n_kv: int, head_dim: int,
               *, window: Optional[int] = None, dtype=jnp.bfloat16) -> KVCache:
    slots = min(window, max_len) if window else max_len
    return KVCache(
        k=jnp.zeros((n_layers, batch, slots, n_kv, head_dim), dtype),
        v=jnp.zeros((n_layers, batch, slots, n_kv, head_dim), dtype),
        positions=jnp.full((batch, slots), -1, jnp.int32),
        length=jnp.zeros((), jnp.int32))


def cache_write(cache_k: jax.Array, cache_v: jax.Array, positions: jax.Array,
                k_new: jax.Array, v_new: jax.Array, pos: jax.Array
                ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Write one token's K/V at slot ``pos % slots`` (ring for SWA).

    cache_k/v: [B, S, Kv, D]; k_new/v_new: [B, 1, Kv, D]; pos: [] int32.
    """
    slots = cache_k.shape[1]
    slot = (pos % slots).astype(jnp.int32)
    ck = jax.lax.dynamic_update_slice(cache_k, k_new.astype(cache_k.dtype),
                                      (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache_v, v_new.astype(cache_v.dtype),
                                      (0, slot, 0, 0))
    pcol = jnp.full((positions.shape[0], 1), pos, jnp.int32)
    pp = jax.lax.dynamic_update_slice(positions, pcol, (0, slot))
    return ck, cv, pp


def attention_decode(q: jax.Array, cache_k: jax.Array, cache_v: jax.Array,
                     slot_positions: jax.Array, pos: jax.Array,
                     window: Optional[int] = None) -> jax.Array:
    """Single-token attention against the cache.

    q: [B, 1, H, D]; cache_k/v: [B, S, Kv, D]; slot_positions: [B, S].
    Returns [B, 1, H, D].
    """
    B, _, H, D = q.shape
    Kv = cache_k.shape[2]
    G = H // Kv
    qf = q.reshape(B, 1, Kv, G, D) * (D ** -0.5)
    s = _gqa_scores(qf, cache_k)[..., 0, :]             # [B, Kv, G, S]
    valid = (slot_positions >= 0) & (slot_positions <= pos)
    if window is not None:
        valid &= slot_positions > pos - window
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s.astype(jnp.float32), axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p, cache_v.astype(jnp.float32))
    return out.reshape(B, 1, H, D).astype(q.dtype)
