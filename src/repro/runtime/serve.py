"""Serving loop: batched prefill + decode with a shared KV/state cache.

The request path mirrors the paper's AXI->WB ingress: requests arrive tagged
with an application ID, the register file's app-destination registers say
which module chain serves them (here: which model), and results stream back
round-robin (§IV-G).

``ServeLoop`` is the fixed-wave engine: it serves one padded batch of
requests to completion before accepting the next wave.  The event-driven
path — admission queue, continuous batching, shell-routed multi-tenant
streams — lives in ``repro.shell.server.ElasticServer``, which builds on the
same model/decode machinery via ``extra_decode_inputs``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.lm import build_model


@dataclasses.dataclass
class Request:
    app_id: int
    prompt: np.ndarray                  # [S] int32
    max_new: int = 16


@dataclasses.dataclass
class Completion:
    app_id: int
    tokens: List[int]
    prefill_s: float
    decode_s: float


def greedy_tokens(logits: jax.Array, vocab: int) -> jax.Array:
    """Greedy next-token over the true vocab (masks the padded tail).

    Shared by the fixed-wave ``ServeLoop`` and the shell's ``ElasticServer``.
    """
    masked = jnp.where(jnp.arange(logits.shape[-1]) < vocab,
                       logits, -jnp.inf)
    return jnp.argmax(masked, axis=-1).astype(jnp.int32)


def extra_decode_inputs(cfg: ModelConfig, batch_size: int,
                        dtype) -> Dict[str, jax.Array]:
    """Per-family auxiliary decode inputs (vision patches, encoder frames).

    Shared by the fixed-wave ``ServeLoop`` and the shell's ``ElasticServer``
    so new model families plug into both paths in one place.
    """
    extras: Dict[str, jax.Array] = {}
    if cfg.family == "encdec":
        extras["frames"] = jnp.zeros(
            (batch_size, cfg.encoder_len, cfg.d_model), dtype)
    return extras


class ServeLoop:
    """Greedy batched serving for one model (one module chain).

    Deprecated: the fixed-wave engine pads every request to the longest in
    its batch and blocks admissions until the wave drains.
    ``repro.shell.server.ElasticServer`` (admission queue + continuous
    batching, shell-routed) is the maintained serving path.
    """

    def __init__(self, cfg: ModelConfig, *, batch: int = 4,
                 max_len: int = 256, seed: int = 0):
        import warnings
        warnings.warn(
            "DEPRECATED runtime.serve.ServeLoop — migrate to "
            "repro.shell.server.ElasticServer (continuous batching, "
            "shell-gated routing; see docs/migration.md)",
            DeprecationWarning, stacklevel=2)
        self.cfg = cfg
        self.model = build_model(cfg)
        self.batch = batch
        self.max_len = max_len
        self.params = self.model.init(jax.random.key(seed))

        def prefill_logits(params, batch_):
            return self.model.prefill(params, batch_)

        def decode_one(params, state, batch_):
            return self.model.decode_step(params, state, batch_)

        self._prefill = jax.jit(prefill_logits)
        self._decode = jax.jit(decode_one, donate_argnums=(1,))

    # ------------------------------------------------------------------
    def _prefill_batch(self, prompts: np.ndarray) -> jax.Array:
        batch = {"tokens": jnp.asarray(prompts)}
        if self.cfg.n_vision_patches:
            batch["patches"] = jnp.zeros(
                (prompts.shape[0], self.cfg.n_vision_patches,
                 self.cfg.d_model), self.model.dtype)
        if self.cfg.family == "encdec":
            batch["frames"] = jnp.zeros(
                (prompts.shape[0], self.cfg.encoder_len, self.cfg.d_model),
                self.model.dtype)
        return self._prefill(self.params, batch)

    def _warm_state(self, prompts: np.ndarray):
        """Replay the prompt through decode_step to build the cache.

        (A production server fuses this into prefill; replay keeps the smoke
        path simple and exercises decode_step S times.)"""
        B, S = prompts.shape
        state = self.model.init_decode_state(B, self.max_len)
        logits = None
        extras = extra_decode_inputs(self.cfg, B, self.model.dtype)
        for t in range(S):
            batch = {"tokens": jnp.asarray(prompts[:, t:t + 1]), **extras}
            logits, state = self._decode(self.params, state, batch)
        return logits, state

    def serve(self, requests: List[Request]) -> List[Completion]:
        """Serve a wave of requests (padded to the fixed batch)."""
        assert requests, "empty request wave"
        assert len(requests) <= self.batch
        S = max(len(r.prompt) for r in requests)
        prompts = np.zeros((self.batch, S), np.int32)
        for i, r in enumerate(requests):
            prompts[i, S - len(r.prompt):] = r.prompt   # left-pad

        t0 = time.monotonic()
        logits, state = self._warm_state(prompts)
        t1 = time.monotonic()

        max_new = max(r.max_new for r in requests)
        out_tokens = np.zeros((self.batch, max_new), np.int32)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        extras = extra_decode_inputs(self.cfg, self.batch, self.model.dtype)
        for j in range(max_new):
            # Mask the vocab padding (argmax over true vocab only).
            out_tokens[:, j] = np.asarray(tok)
            batch = {"tokens": tok[:, None], **extras}
            logits, state = self._decode(self.params, state, batch)
            tok = greedy_tokens(logits, self.cfg.vocab)
        t2 = time.monotonic()

        return [Completion(app_id=r.app_id,
                           tokens=list(out_tokens[i, :r.max_new]),
                           prefill_s=t1 - t0, decode_s=t2 - t1)
                for i, r in enumerate(requests)]
