"""Serving loop: batched prefill + decode with a shared KV/state cache.

The request path mirrors the paper's AXI->WB ingress: requests arrive tagged
with an application ID, the register file's app-destination registers say
which module chain serves them (here: which model), and results stream back
round-robin (§IV-G). Batched continuous decode keeps one decode-state pytree
alive and rotates finished slots to new requests.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.lm import build_model


@dataclasses.dataclass
class Request:
    app_id: int
    prompt: np.ndarray                  # [S] int32
    max_new: int = 16


@dataclasses.dataclass
class Completion:
    app_id: int
    tokens: List[int]
    prefill_s: float
    decode_s: float


class ServeLoop:
    """Greedy batched serving for one model (one module chain)."""

    def __init__(self, cfg: ModelConfig, *, batch: int = 4,
                 max_len: int = 256, seed: int = 0):
        self.cfg = cfg
        self.model = build_model(cfg)
        self.batch = batch
        self.max_len = max_len
        self.params = self.model.init(jax.random.key(seed))

        def prefill_logits(params, batch_):
            return self.model.prefill(params, batch_)

        def decode_one(params, state, batch_):
            return self.model.decode_step(params, state, batch_)

        self._prefill = jax.jit(prefill_logits)
        self._decode = jax.jit(decode_one, donate_argnums=(1,))

    # ------------------------------------------------------------------
    def _prefill_batch(self, prompts: np.ndarray) -> jax.Array:
        batch = {"tokens": jnp.asarray(prompts)}
        if self.cfg.n_vision_patches:
            batch["patches"] = jnp.zeros(
                (prompts.shape[0], self.cfg.n_vision_patches,
                 self.cfg.d_model), self.model.dtype)
        if self.cfg.family == "encdec":
            batch["frames"] = jnp.zeros(
                (prompts.shape[0], self.cfg.encoder_len, self.cfg.d_model),
                self.model.dtype)
        return self._prefill(self.params, batch)

    def _warm_state(self, prompts: np.ndarray):
        """Replay the prompt through decode_step to build the cache.

        (A production server fuses this into prefill; replay keeps the smoke
        path simple and exercises decode_step S times.)"""
        B, S = prompts.shape
        state = self.model.init_decode_state(B, self.max_len)
        logits = None
        for t in range(S):
            batch = {"tokens": jnp.asarray(prompts[:, t:t + 1])}
            if self.cfg.family == "encdec":
                batch["frames"] = jnp.zeros(
                    (B, self.cfg.encoder_len, self.cfg.d_model),
                    self.model.dtype)
            logits, state = self._decode(self.params, state, batch)
        return logits, state

    def serve(self, requests: List[Request]) -> List[Completion]:
        """Serve a wave of requests (padded to the fixed batch)."""
        assert requests, "empty request wave"
        assert len(requests) <= self.batch
        S = max(len(r.prompt) for r in requests)
        prompts = np.zeros((self.batch, S), np.int32)
        for i, r in enumerate(requests):
            prompts[i, S - len(r.prompt):] = r.prompt   # left-pad

        t0 = time.monotonic()
        logits, state = self._warm_state(prompts)
        t1 = time.monotonic()

        max_new = max(r.max_new for r in requests)
        out_tokens = np.zeros((self.batch, max_new), np.int32)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        for j in range(max_new):
            # Mask the vocab padding (argmax over true vocab only).
            out_tokens[:, j] = np.asarray(tok)
            batch = {"tokens": tok[:, None]}
            if self.cfg.family == "encdec":
                batch["frames"] = jnp.zeros(
                    (self.batch, self.cfg.encoder_len, self.cfg.d_model),
                    self.model.dtype)
            logits, state = self._decode(self.params, state, batch)
            tok = jnp.argmax(
                jnp.where(jnp.arange(logits.shape[-1]) < self.cfg.vocab,
                          logits, -jnp.inf), axis=-1).astype(jnp.int32)
        t2 = time.monotonic()

        return [Completion(app_id=r.app_id,
                           tokens=list(out_tokens[i, :r.max_new]),
                           prefill_s=t1 - t0, decode_s=t2 - t1)
                for i, r in enumerate(requests)]
