"""Fault tolerance: heartbeats, step watchdogs, straggler statistics.

The paper's WB interfaces carry *watchdog timers*: a master that waits too
long for a grant or an ack raises GRANT_TIMEOUT / ACK_TIMEOUT and the error
code lands in the register file for the manager to read (§IV-F). The fleet
runtime keeps exactly that contract at step granularity:

- ``StepWatchdog``    — per-step deadline; a blown deadline is the ack-
  timeout analogue and marks the step's region as *suspect*;
- ``HeartbeatMonitor``— regions report liveness; a missed-heartbeat region is
  *failed* and handed to the ElasticResourceManager (demote-to-host path);
- ``StragglerStats``  — EWMA of per-region step times; persistent outliers
  (> ``threshold`` x fleet median for ``patience`` consecutive steps) trigger
  region reassignment, the paper's "switch the grant to the next master".

Event wiring: both monitors speak the unified shell vocabulary.  Attach a
``repro.shell.Shell`` (or pass ``on_timeout`` for the watchdog) and a missed
heartbeat posts ``HeartbeatLost``, a heal posts ``HealRegion``, and a blown
step deadline posts ``WatchdogTimeout`` — no example-level polling glue
needed.  The legacy ``erm=`` arguments remain for the wrapper API.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

from repro.core.registers import ErrorCode
from repro.shell.events import HealRegion, HeartbeatLost, WatchdogTimeout


@dataclasses.dataclass
class WatchdogEvent:
    step: int
    region: Optional[int]
    elapsed_s: float
    deadline_s: float
    error: int = int(ErrorCode.ACK_TIMEOUT)


class StepWatchdog:
    """Per-step deadline — the WB watchdog at step granularity.

    ``on_timeout`` (or an attached ``shell``) receives every blown deadline;
    a shell gets it as a ``WatchdogTimeout`` event so demotion happens
    through the planner, not through caller-side polling of ``events``.
    """

    def __init__(self, deadline_s: float, *,
                 on_timeout: Optional[Callable[[WatchdogEvent], None]] = None,
                 shell=None):
        self.deadline_s = deadline_s
        self.events: List[WatchdogEvent] = []
        self.on_timeout = on_timeout
        self.shell = shell
        self._t0: Optional[float] = None
        self._step = -1

    def arm(self, step: int) -> None:
        self._t0 = time.monotonic()
        self._step = step

    def check(self, region: Optional[int] = None) -> bool:
        """True if the armed step beat its deadline."""
        assert self._t0 is not None, "watchdog not armed"
        elapsed = time.monotonic() - self._t0
        ok = elapsed <= self.deadline_s
        if not ok:
            event = WatchdogEvent(self._step, region, elapsed,
                                  self.deadline_s)
            self.events.append(event)
            if self.on_timeout is not None:
                self.on_timeout(event)
            if self.shell is not None:
                self.shell.post(WatchdogTimeout(
                    step=event.step, region=event.region,
                    elapsed_s=event.elapsed_s,
                    deadline_s=event.deadline_s))
        return ok


class HeartbeatMonitor:
    """Region liveness; emits shell events (or drives the legacy ERM).

    Attach a ``repro.shell.Shell`` and every stale heartbeat posts a
    ``HeartbeatLost`` event (the planner demotes the region's module), every
    heal posts ``HealRegion`` (the planner promotes waiters).  The ``erm=``
    arguments keep the seed's polled integration working.
    """

    def __init__(self, region_ids: Optional[List[int]] = None,
                 timeout_s: float = 30.0,
                 clock: Callable[[], float] = time.monotonic, *,
                 shell=None):
        if region_ids is None:
            if shell is None:
                raise ValueError(
                    "HeartbeatMonitor needs region_ids or a shell to "
                    "derive them from")
            region_ids = [r.rid for r in shell.state.regions]
        self.timeout_s = timeout_s
        self.shell = shell
        self._clock = clock
        now = clock()
        self.last_beat: Dict[int, float] = {r: now for r in region_ids}
        self.failed: Dict[int, float] = {}

    def monitored_ids(self) -> List[int]:
        """The regions this sweep watches.  With a shell attached this is
        the *live* pool (a statically passed list would go stale as the
        pool changes); standalone it is the constructor's list."""
        if self.shell is not None:
            return [r.rid for r in self.shell.state.regions]
        return list(self.last_beat)

    def beat(self, region: int) -> None:
        self.last_beat[region] = self._clock()
        if region in self.failed:
            del self.failed[region]

    def sweep(self, erm=None) -> List[int]:
        """Mark regions with stale heartbeats failed; emit events/demote."""
        now = self._clock()
        newly_failed = []
        for region in self.monitored_ids():
            # A region first seen by this sweep (joined the pool after
            # construction) baselines now rather than failing instantly.
            t = self.last_beat.setdefault(region, now)
            if region in self.failed:
                continue
            if now - t > self.timeout_s:
                self.failed[region] = now
                newly_failed.append(region)
                if erm is not None:
                    erm.fail_region(region)
                if self.shell is not None:
                    self.shell.post(HeartbeatLost(rid=region,
                                                  stale_s=now - t))
        return newly_failed

    def heal(self, region: int, erm=None) -> None:
        self.beat(region)
        if erm is not None:
            erm.heal_region(region)
        if self.shell is not None:
            self.shell.post(HealRegion(rid=region))


class StragglerStats:
    """EWMA step times per region; flags persistent stragglers.

    With a ``shell`` attached, :meth:`sweep` posts a ``WatchdogTimeout``
    event for every *newly* flagged straggler (once per streak — the
    planner demotes the region; re-posting while it is already failed
    would be noise), closing the poll-only gap: ``TrainLoop`` feeds its
    per-step times here and stragglers demote through the event bus with
    no example-level polling.
    """

    def __init__(self, region_ids: Optional[List[int]] = None,
                 alpha: float = 0.3,
                 threshold: float = 1.5, patience: int = 3, *,
                 shell=None):
        if region_ids is None:
            if shell is None:
                raise ValueError(
                    "StragglerStats needs region_ids or a shell to derive "
                    "them from")
            region_ids = [r.rid for r in shell.state.regions]
        self.alpha = alpha
        self.threshold = threshold
        self.patience = patience
        self.shell = shell
        self.ewma: Dict[int, Optional[float]] = {r: None for r in region_ids}
        self.strikes: Dict[int, int] = {r: 0 for r in region_ids}
        self._reported: set = set()
        self._dirty: set = set()

    def scores(self) -> Dict[int, float]:
        """EWMA-to-fleet-median ratio per recorded region (1.0 == typical;
        above ``threshold`` feeds a strike).  The manager's straggler
        signal."""
        med = self._median()
        if not med:
            return {}
        return {r: v / med for r, v in self.ewma.items() if v is not None}

    def probe(self):
        """A ``repro.manager`` telemetry probe over these statistics."""
        from repro.manager.telemetry import StragglerProbe
        return StragglerProbe(self)

    def record(self, region: int, step_s: float) -> None:
        prev = self.ewma.get(region)
        self.ewma[region] = (step_s if prev is None
                             else self.alpha * step_s
                             + (1 - self.alpha) * prev)
        self.strikes.setdefault(region, 0)    # regions may join the fleet late
        self._dirty.add(region)

    def _median(self) -> Optional[float]:
        vals = sorted(v for v in self.ewma.values() if v is not None)
        if not vals:
            return None
        return vals[len(vals) // 2]

    def stragglers(self) -> List[int]:
        """Regions whose EWMA exceeded threshold x median for ``patience``
        consecutive *recorded* steps.

        A region's strike count advances only when a new ``record`` for it
        arrived since the last call — so with stats shared fleet-wide,
        every loop sweeping on its own step advances its own region's
        streak once per step, not once per peer sweep (one transiently
        slow step cannot burn through ``patience``)."""
        med = self._median()
        out = []
        if med is None or med == 0:
            return out
        for region, v in self.ewma.items():
            if region in self._dirty:
                self._dirty.discard(region)
                if v is not None and v > self.threshold * med:
                    self.strikes[region] += 1
                else:
                    self.strikes[region] = 0
                    self._reported.discard(region)
            if self.strikes[region] >= self.patience:
                out.append(region)
        return out

    def sweep(self, step: int = -1) -> List[int]:
        """Flag stragglers and post ``WatchdogTimeout`` for new ones.

        Returns the currently flagged regions.  Emission is once per
        straggler streak and only while the region is still healthy in the
        shell's pool (the resulting demote makes a second post redundant).
        """
        out = self.stragglers()
        if self.shell is None:
            return out
        med = self._median() or 0.0
        for region in out:
            if region in self._reported:
                continue
            try:
                healthy = self.shell.state.region(region).healthy
            except (KeyError, IndexError):
                continue          # unknown to this pool: retry next sweep
            self._reported.add(region)
            if healthy:
                self.shell.post(WatchdogTimeout(
                    step=step, region=region,
                    elapsed_s=float(self.ewma[region] or 0.0),
                    deadline_s=self.threshold * med))
        return out
