"""Training loop: data feed, jit'd step, checkpoints, fault tolerance.

The loop is mesh-agnostic: on this CPU container it drives smoke-scale
models on a 1-device mesh; on a pod it drives the same ``StepBundle`` the
dry-run lowers (same in/out shardings, same donation). Crash-restart is a
constructor flag — the loop resumes from the newest committed checkpoint and
re-seeds the data pipeline from the restored step (pure-function batches
make that exact).
"""
from __future__ import annotations

import dataclasses
import time
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt.checkpoint import CheckpointManager
from repro.data.pipeline import DataPipeline
from repro.models.config import ModelConfig
from repro.models.lm import build_model
from repro.optim.adamw import AdamW, OptState, cosine_schedule
from repro.runtime.ft import StepWatchdog


@dataclasses.dataclass
class TrainLoopConfig:
    steps: int = 100
    global_batch: int = 8
    seq_len: int = 128
    seed: int = 0
    lr: float = 3e-4
    warmup: int = 20
    ckpt_every: int = 50
    ckpt_keep: int = 2
    step_deadline_s: float = 300.0
    log_every: int = 10


class TrainLoop:
    def __init__(self, cfg: ModelConfig, run: TrainLoopConfig,
                 ckpt_dir: Optional[Path] = None, *,
                 resume: bool = False,
                 on_log: Optional[Callable[[Dict[str, Any]], None]] = None,
                 shell=None, region: Optional[int] = None,
                 straggler_stats=None):
        self.cfg = cfg
        self.run = run
        self.model = build_model(cfg)
        self.opt = AdamW(lr=cosine_schedule(run.lr, run.warmup, run.steps))
        self.on_log = on_log or (lambda rec: None)
        # With a repro.shell.Shell attached, blown step deadlines surface as
        # WatchdogTimeout events on the shell's bus instead of requiring the
        # caller to poll ``watchdog.events``.
        self.shell = shell
        self.watchdog = StepWatchdog(run.step_deadline_s, shell=shell)
        # Fleet straggler detection: pass a StragglerStats shared across
        # the fleet's loops (each loop records its own ``region``); a
        # persistent straggler posts WatchdogTimeout through the shell —
        # no polling of ``stats.stragglers()`` needed.  ``region`` also
        # attributes blown step deadlines: with it set, a WatchdogTimeout
        # names this loop's region and the planner demotes it (without it
        # the event stays informational, as before).
        self.region = region
        self.straggler_stats = straggler_stats
        if (straggler_stats is not None and straggler_stats.shell is None
                and shell is not None):
            straggler_stats.shell = shell
        self.ckpt = (CheckpointManager(ckpt_dir, keep=run.ckpt_keep)
                     if ckpt_dir is not None else None)
        self.history: List[Dict[str, Any]] = []

        self.pipeline = DataPipeline(
            seed=run.seed, global_batch=run.global_batch,
            seq_len=run.seq_len, vocab=cfg.vocab, kind="train")

        def train_step(params, opt_state: OptState, batch):
            loss, grads = jax.value_and_grad(self.model.loss)(params, batch)
            updates, opt_state = self.opt.update(grads, opt_state, params)
            params = AdamW.apply_updates(params, updates)
            return params, opt_state, loss

        self._step_fn = jax.jit(train_step, donate_argnums=(0, 1))

        # --- init or resume -------------------------------------------
        self.params = self.model.init(jax.random.key(run.seed))
        self.opt_state = self.opt.init(self.params)
        self.start_step = 0
        if resume and self.ckpt is not None:
            got = self.ckpt.restore_latest((self.params, self.opt_state))
            if got is not None:
                self.start_step, (self.params, self.opt_state) = got
        self.pipeline.restore(
            dataclasses.replace(self.pipeline.state(), step=self.start_step))

    # ------------------------------------------------------------------
    def probe(self):
        """A ``repro.manager`` telemetry probe over this loop's fleet
        straggler statistics (requires ``straggler_stats=``)."""
        if self.straggler_stats is None:
            raise ValueError("TrainLoop.probe() needs straggler_stats=")
        return self.straggler_stats.probe()

    # ------------------------------------------------------------------
    def run_loop(self) -> List[Dict[str, Any]]:
        run = self.run
        self.pipeline.start()
        try:
            for step in range(self.start_step, run.steps):
                self.watchdog.arm(step)
                t0 = time.monotonic()
                batch_np = next(self.pipeline)
                batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
                self.params, self.opt_state, loss = self._step_fn(
                    self.params, self.opt_state, batch)
                loss = float(loss)
                dt = time.monotonic() - t0
                self.watchdog.check(region=self.region)
                if (self.straggler_stats is not None
                        and self.region is not None):
                    # no region identity -> nothing to attribute: recording
                    # under a default id could demote someone else's region
                    self.straggler_stats.record(self.region, dt)
                    self.straggler_stats.sweep(step=step)

                if step % run.log_every == 0 or step == run.steps - 1:
                    rec = {"step": step, "loss": loss, "step_s": dt}
                    self.history.append(rec)
                    self.on_log(rec)
                if np.isnan(loss):
                    raise FloatingPointError(f"NaN loss at step {step}")
                if self.ckpt is not None and (step + 1) % run.ckpt_every == 0:
                    self.ckpt.save_async(step + 1,
                                         (self.params, self.opt_state),
                                         extra={"loss": loss})
        finally:
            self.pipeline.stop()
            if self.ckpt is not None:
                self.ckpt.wait()
        return self.history
