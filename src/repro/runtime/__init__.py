from repro.runtime.ft import (HeartbeatMonitor, StepWatchdog,  # noqa: F401
                              StragglerStats)
from repro.runtime.serve import ServeLoop  # noqa: F401
from repro.runtime.train import TrainLoop, TrainLoopConfig  # noqa: F401
