from repro.runtime.ft import (HeartbeatMonitor, StepWatchdog,  # noqa: F401
                              StragglerStats)
from repro.runtime.serve import ServeLoop  # noqa: F401  # fablint: disable=FAB003 (back-compat re-export)
from repro.runtime.train import TrainLoop, TrainLoopConfig  # noqa: F401
