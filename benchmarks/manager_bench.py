"""Closed-loop autoscaling trajectory bench for ``repro.manager``.

Runs the seeded scenario harness under the reactive policies
(Hysteresis + TrafficAwareDefrag chain, FairShare) and the predictive
``PredictiveSLO`` chain, and reports *counting* metrics only —
completions, event mix, peak queue, rejected posts, fabric retraces, SLO
violation ticks — never wall time.  Every number is a pure function of
the seed, so ``BENCH_manager.json`` (written by ``benchmarks/run.py``)
is byte-stable across machines and diffs cleanly per PR: a policy change
shows up as a changed event mix, a retrace regression as
``fabric_retraces > 1``, a forecasting regression as
``forecastable_violations > 0``.

Row kinds:

- plain scenario rows (``RUNS``) — the original reactive trajectories,
  plus a multi-server ``production`` run (hundreds of tenants, heavy-
  tailed schedule, 4 frontends over one shell).
- ``mode="slo_compare"`` rows (``SLO_RUNS``) — reactive vs predictive on
  the same seeded grant-coupled scenario.  Gated by
  ``tools/check_bench_regression.py --manager-json``: the predictive run
  must leave zero forecastable violations and strictly fewer violation
  ticks than the reactive baseline (when the baseline has any).
- one ``mode="trace_replay"`` row — records a churn workload to
  ``benchmarks/manager_trace.jsonl`` (the CI artifact), replays it, and
  reports whether the two result JSONs are bit-identical.
- ``mode="isolation"`` rows (``ISOLATION_RUNS``) — the adversarial
  scenario run twice per seed: once quiet (same honest tenants, no
  attackers) and once under attack.  The attack run records to
  ``benchmarks/manager_attack_trace.jsonl`` (the CI artifact).  Gated by
  ``--manager-json``: honest-tenant admission p99 under attack stays
  within ``p99_bound`` of the quiet twin, every masked packet is charged
  to an attacker-owned source port, and ``fabric_retraces`` holds at 1
  through the attack.

``bench_manager(mode="predictive")`` runs only the gated predictive rows
— the fast CI smoke; ``mode="adversarial"`` only the isolation rows.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List, Tuple

# CI smoke runs this; keep the grid small and the ticks short.
RUNS = [
    ("bursty", "default", 0, 40),
    ("churn", "default", 0, 48),
    ("failure_storm", "default", 0, 40),
    ("churn", "fair_share", 1, 48),
]

# Reactive-vs-predictive comparison grid: (kind, seed, ticks).  Seeds are
# committed: on each, the predictive run beats the reactive baseline
# (strictly fewer violation ticks) with zero forecastable violations —
# the property tests in tests/test_forecast.py pin the same seeds.
SLO_RUNS = [
    ("diurnal", 0, 96),
    ("diurnal", 2, 96),
    ("diurnal", 5, 96),
    ("bursty", 1, 72),
    ("bursty", 2, 72),
    ("bursty", 5, 72),
]

TRACE_ARTIFACT = Path(__file__).resolve().parent / "manager_trace.jsonl"
ATTACK_TRACE = Path(__file__).resolve().parent / "manager_attack_trace.jsonl"

# Isolation grid: (seed, ticks, attacker mix).  Cascade-failer mixes are
# deliberately excluded here: region failures legitimately mask honest
# traffic in flight, which would void the masked_honest_src == 0 gate
# (tests/test_adversary.py covers those mixes property-wise instead).
ISOLATION_RUNS = [
    (0, 40, ("noisy_neighbor", "dest_sprayer")),
    (1, 40, ("noisy_neighbor", "dest_sprayer", "drop_retrier")),
]

# The gate bound: honest-tenant admission p99 under attack must stay
# within this multiple of the quiet twin (floored at 1 tick).
ISOLATION_P99_BOUND = 4.0


def _honest_p99(res) -> float:
    """Admission p99 (ticks) over honest-tenant completions only —
    attacker app_ids live at >= 10 by construction in ``build_spec``."""
    from repro.stats import percentile

    waits = [c.admitted_tick - c.submitted_tick
             for c in res.server.completions
             if c.app_id < 10 and c.submitted_tick >= 0]
    return round(percentile(waits, 99.0), 3) if waits else 0.0


def _isolation_rows() -> List[dict]:
    from repro.manager import adversarial_policy, build_spec, run_scenario

    rows = []
    for seed, ticks, mix in ISOLATION_RUNS:
        per = {}
        for label, attackers, record in (("quiet", (), None),
                                         ("attack", mix, ATTACK_TRACE)):
            spec = build_spec("adversarial", ticks=ticks, seed=seed,
                              attackers=attackers)
            per[label] = run_scenario(spec, seed=seed, ticks=ticks,
                                      policy=adversarial_policy(),
                                      record_path=record)
        quiet, attack = per["quiet"], per["attack"]
        masked = [int(v) for v in attack.server.masked_by_src]
        rows.append({
            "mode": "isolation",
            "scenario": "adversarial", "seed": seed, "ticks": ticks,
            "attackers": list(mix),
            "p99_bound": ISOLATION_P99_BOUND,
            "honest_p99_quiet": _honest_p99(quiet),
            "honest_p99_attack": _honest_p99(attack),
            "honest_completions_quiet": sum(
                1 for c in quiet.server.completions if c.app_id < 10),
            "honest_completions_attack": sum(
                1 for c in attack.server.completions if c.app_id < 10),
            "masked_attacker_src": sum(masked[1:]),
            "masked_honest_src": masked[0] if masked else 0,
            "quiet_retraces": quiet.fabric_retraces,
            "attack_retraces": attack.fabric_retraces,
            "artifact": ATTACK_TRACE.name,
        })
    return rows


def _slo_compare_rows() -> List[dict]:
    from repro.manager import (build_spec, default_policy, predictive_policy,
                               run_scenario)

    rows = []
    for kind, seed, ticks in SLO_RUNS:
        per = {}
        for policy_name, mk in (("default", default_policy),
                                ("predictive_slo", predictive_policy)):
            spec = build_spec(kind, ticks=ticks, seed=seed,
                              slots_per_region=2)
            res = run_scenario(spec, seed=seed, ticks=ticks, n_slots=16,
                               policy=mk())
            per[policy_name] = res
        rea, pre = per["default"], per["predictive_slo"]
        rows.append({
            "mode": "slo_compare",
            "scenario": kind, "seed": seed, "ticks": ticks,
            "slots_per_region": 2,
            "reactive_violation_ticks": rea.slo_violation_ticks,
            "reactive_violations": rea.slo_violations,
            "reactive_forecastable": len(rea.forecastable),
            "predictive_violation_ticks": pre.slo_violation_ticks,
            "predictive_violations": pre.slo_violations,
            "predictive_forecastable": len(pre.forecastable),
            "reactive_retraces": rea.fabric_retraces,
            "predictive_retraces": pre.fabric_retraces,
            "predictive_completions": pre.completions,
        })
    return rows


def _trace_replay_row() -> dict:
    from repro.manager import (RecordedWorkload, predictive_policy,
                               run_scenario)

    a = run_scenario("churn", seed=3, ticks=30,
                     policy=predictive_policy(),
                     record_path=TRACE_ARTIFACT)
    b = run_scenario(RecordedWorkload.load(TRACE_ARTIFACT),
                     policy=predictive_policy())
    identical = (json.dumps(a.to_json(), sort_keys=True)
                 == json.dumps(b.to_json(), sort_keys=True))
    return {
        "mode": "trace_replay",
        "scenario": "churn", "seed": 3, "ticks": 30,
        "bit_identical": identical,
        "recorded_rows": len(RecordedWorkload.load(TRACE_ARTIFACT).rows),
        "record_retraces": a.fabric_retraces,
        "replay_retraces": b.fabric_retraces,
        "artifact": TRACE_ARTIFACT.name,
    }


def bench_manager(mode: str = "all") -> Tuple[List[dict], Dict[str, str]]:
    from repro.manager import FairShare, default_policy, run_scenario

    rows: List[dict] = []
    if mode == "all":
        for kind, policy_name, seed, ticks in RUNS:
            policy = (FairShare() if policy_name == "fair_share"
                      else default_policy())
            res = run_scenario(kind, seed=seed, ticks=ticks, policy=policy)
            rows.append({"policy": policy_name, **res.summary()})
        res = run_scenario("production", seed=0, ticks=48, n_regions=24,
                           n_slots=16, n_servers=4,
                           policy=default_policy())
        rows.append({"policy": "default", "mode": "production",
                     **res.summary()})
    if mode == "adversarial":
        rows += _isolation_rows()
        claims = {
            "isolation": ("isolation rows: honest-tenant admission p99 "
                          "under attack stays within p99_bound of the "
                          "quiet twin, masked packets are charged only "
                          "to attacker-owned source ports, and "
                          "fabric_retraces == 1 throughout (gated by "
                          "--manager-json)"),
        }
        return rows, claims
    rows += _slo_compare_rows()
    rows.append(_trace_replay_row())
    if mode == "all":
        rows += _isolation_rows()
    claims = {
        "closed_loop": ("every Grow/Shrink/Migrate in these runs was "
                        "posted by the Manager from Signals; the scenario "
                        "layer only posts arrivals/departures/faults"),
        "deterministic": "seeded rng end-to-end; identical rows per seed",
        "zero_retrace": "fabric_retraces is 1 per run (the initial "
                        "compile) — reconfigurations reuse compiled plans",
        "predictive_slo": ("slo_compare rows: PredictiveSLO leaves zero "
                           "forecastable violations and strictly fewer "
                           "violation ticks than the reactive baseline "
                           "on the same seed (gated by --manager-json)"),
        "record_replay": ("trace_replay row: a recorded workload replays "
                          "to a bit-identical result JSON"),
    }
    if mode == "all":
        claims["isolation"] = (
            "isolation rows: honest-tenant admission p99 under attack "
            "stays within p99_bound of the quiet twin, masked packets "
            "are charged only to attacker-owned source ports, and "
            "fabric_retraces == 1 throughout (gated by --manager-json)")
    return rows, claims


def bench_manager_predictive() -> Tuple[List[dict], Dict[str, str]]:
    """The ``--predictive`` CI smoke: only the gated rows."""
    return bench_manager(mode="predictive")


def bench_manager_adversarial() -> Tuple[List[dict], Dict[str, str]]:
    """The ``--adversarial`` CI smoke: quiet-vs-attack isolation rows
    only, recording the attack trace artifact."""
    return bench_manager(mode="adversarial")
