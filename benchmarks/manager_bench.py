"""Closed-loop autoscaling trajectory bench for ``repro.manager``.

Runs the seeded scenario harness (bursty / churn / failure_storm) under the
default Hysteresis + TrafficAwareDefrag chain and a FairShare run, and
reports *counting* metrics only — completions, event mix, peak queue,
rejected posts, fabric retraces — never wall time.  Every number is a pure
function of the seed, so ``BENCH_manager.json`` (written by
``benchmarks/run.py``) is byte-stable across machines and diffs cleanly
per PR: a policy change shows up as a changed event mix, a retrace
regression as ``fabric_retraces > 1``.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

# CI smoke runs this; keep the grid small and the ticks short.
RUNS = [
    ("bursty", "default", 0, 40),
    ("churn", "default", 0, 48),
    ("failure_storm", "default", 0, 40),
    ("churn", "fair_share", 1, 48),
]


def bench_manager() -> Tuple[List[dict], Dict[str, str]]:
    from repro.manager import FairShare, default_policy, run_scenario

    rows = []
    for kind, policy_name, seed, ticks in RUNS:
        policy = (FairShare() if policy_name == "fair_share"
                  else default_policy())
        res = run_scenario(kind, seed=seed, ticks=ticks, policy=policy)
        rows.append({"policy": policy_name, **res.summary()})
    claims = {
        "closed_loop": ("every Grow/Shrink/Migrate in these runs was "
                        "posted by the Manager from Signals; the scenario "
                        "layer only posts arrivals/departures/faults"),
        "deterministic": "seeded rng end-to-end; identical rows per seed",
        "zero_retrace": "fabric_retraces is 1 per run (the initial "
                        "compile) — reconfigurations reuse compiled plans",
    }
    return rows, claims
