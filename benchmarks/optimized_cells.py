"""Run the three hillclimbed cells with the beyond-paper optimizations and
emit the baseline-vs-optimized comparison for EXPERIMENTS.md §Perf.

    PYTHONPATH=src python -m benchmarks.optimized_cells

Baseline = the corrected framework (activation constraints, dense MoE
dispatch) from experiments/dryrun/*_pod.json; optimized runs land in
experiments/optimized/.
"""
from __future__ import annotations

import dataclasses as dc
import json
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
BASE = ROOT / "experiments" / "dryrun"
OPT = ROOT / "experiments" / "optimized"

CELLS = [
    # (arch, shape, overrides-builder, tag)
    ("mixtral_8x7b", "prefill_32k", "gather", "moe-gather dispatch"),
    ("mixtral_8x7b", "train_4k", "gather", "moe-gather dispatch"),
    ("command_r_plus_104b", "prefill_32k", None,
     "constraints only (no further confirmed mover)"),
    ("tinyllama_1_1b", "train_4k", None,
     "constraints only (remat/kv knobs refuted)"),
]


def overrides_for(kind, cfg):
    if kind == "gather":
        return {"moe": dc.replace(cfg.moe, dispatch="gather")}
    return None


def main():
    from repro.configs import get_config
    from repro.launch.dryrun import run_cell

    rows = []
    for arch, shape, okind, tag in CELLS:
        cfg = get_config(arch)
        ov = overrides_for(okind, cfg)
        base = json.loads((BASE / f"{arch}_{shape}_pod.json").read_text())
        if ov is None:
            rec = base
        else:
            rec = run_cell(arch, shape, False, OPT, overrides=ov)
        rows.append((arch, shape, tag, base, rec))

    print("\n| cell | change | t_comp (s) | t_mem (s) | t_coll (s) | "
          "bottleneck | roofline frac |")
    print("|---|---|---|---|---|---|---|")
    for arch, shape, tag, base, rec in rows:
        for label, r in (("baseline", base),
                         ("optimized" if r_is_diff(base, rec) else "(= baseline)", rec)):
            print(f"| {arch}/{shape} | {label}: {tag if label != 'baseline' else 'dense/corrected'} | "
                  f"{r['t_compute']:.3g} | {r['t_memory']:.3g} | "
                  f"{r['t_collective']:.3g} | {r['bottleneck']} | "
                  f"{r['roofline_fraction']:.4f} |")
            if r is rec and r is base:
                break


def r_is_diff(a, b):
    return a is not b


if __name__ == "__main__":
    main()
