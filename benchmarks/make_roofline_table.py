"""Render EXPERIMENTS.md §Roofline table from experiments/dryrun/*_pod.json.

    PYTHONPATH=src python -m benchmarks.make_roofline_table [--update]

--update splices the table into EXPERIMENTS.md at TABLE_PLACEHOLDER.
"""
from __future__ import annotations

import json
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
DRY = ROOT / "experiments" / "dryrun"

MOVE_NOTE = {
    "compute": "raise arithmetic intensity (fuse, larger per-chip tiles)",
    "memory": "cut activation round-trips (kernel fusion / flash-style "
              "attention keeps scores in VMEM)",
    "collective": "overlap or shrink collectives (reduce-scatter grads, "
                  "quantise pod-axis traffic, larger per-device batch)",
}


def make_rows():
    rows = []
    for f in sorted(DRY.glob("*_pod.json")):
        r = json.loads(f.read_text())
        if r.get("skipped"):
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "skip": True})
            continue
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "skip": False,
            "tc": r["t_compute"], "tm": r["t_memory"],
            "tl": r["t_collective"], "bn": r["bottleneck"],
            "ur": r.get("useful_flops_ratio"),
            "ub": r.get("useful_bytes_ratio"),
            "rf": r.get("roofline_fraction"),
            "mb": r.get("microbatches", 1),
            "fits": r.get("fits_hbm"),
            "peak": r.get("peak_memory_bytes"),
            "kind": r.get("kind", "?"),
        })
    return rows


def fmt(x, n=3):
    if x is None:
        return "—"
    return f"{x:.{n}g}"


def render() -> str:
    rows = make_rows()
    out = [
        "| arch | shape | t_compute (s) | t_memory (s) | t_coll (s) | "
        "bottleneck | useful/HLO | roofline frac | µb | fits 16 GB | "
        "what moves the dominant term |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r["skip"]:
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | SKIP "
                       f"(full attention @524k) | — | — | — | — | — |")
            continue
        useful = r["ub"] if r["kind"] == "decode" else r["ur"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {fmt(r['tc'])} | {fmt(r['tm'])}"
            f" | {fmt(r['tl'])} | {r['bn']} | {fmt(useful)} | "
            f"{fmt(r['rf'])} | {r['mb']} | "
            f"{'yes' if r['fits'] else 'NO'} | {MOVE_NOTE[r['bn']]} |")
    live = [r for r in rows if not r["skip"]]
    bn = {k: sum(1 for r in live if r["bn"] == k)
          for k in ("compute", "memory", "collective")}
    out.append("")
    out.append(f"Live cells: {len(live)}; skips: {len(rows) - len(live)}. "
               f"Bottleneck census: {bn}. "
               f"(useful/HLO column: MODEL_FLOPS/HLO_FLOPs for train/prefill,"
               f" model_bytes/HLO_bytes for decode.)")
    return "\n".join(out)


def main():
    table = render()
    if "--update" in sys.argv:
        exp = ROOT / "EXPERIMENTS.md"
        text = exp.read_text()
        if "TABLE_PLACEHOLDER" in text:
            exp.write_text(text.replace("TABLE_PLACEHOLDER", table))
            print("EXPERIMENTS.md updated")
        else:
            print("placeholder missing; printing")
            print(table)
    else:
        print(table)


if __name__ == "__main__":
    main()
