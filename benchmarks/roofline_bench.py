"""Roofline benchmark: aggregates the dry-run JSONs into the §Roofline table.

Reads experiments/dryrun/*.json (produced by ``repro.launch.dryrun``) and
emits one row per (arch x shape x mesh) with the three roofline terms, the
bottleneck, and the roofline fraction. This is the harness behind
EXPERIMENTS.md §Roofline — run the dry-run first.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List, Tuple

DRYRUN_DIR = Path(__file__).resolve().parent.parent / "experiments" / "dryrun"


def load_rows(mesh: str = "pod") -> List[dict]:
    rows = []
    for f in sorted(DRYRUN_DIR.glob(f"*_{mesh}.json")):
        rec = json.loads(f.read_text())
        if rec.get("skipped"):
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "mesh": mesh, "skipped": rec["reason"]})
            continue
        rows.append({
            "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
            "t_compute_s": rec["t_compute"], "t_memory_s": rec["t_memory"],
            "t_collective_s": rec["t_collective"],
            "bottleneck": rec["bottleneck"],
            "useful_flops_ratio": rec["useful_flops_ratio"],
            "roofline_fraction": rec["roofline_fraction"],
        })
    return rows


def bench_roofline() -> Tuple[List[dict], Dict[str, str]]:
    rows = load_rows("pod")
    live = [r for r in rows if "skipped" not in r]
    claims = {"cells": len(rows), "live": len(live),
              "note": "full table + per-cell analysis in EXPERIMENTS.md"}
    if live:
        worst = min(live, key=lambda r: r["roofline_fraction"] or 1)
        best = max(live, key=lambda r: r["roofline_fraction"] or 0)
        claims["worst"] = (f"{worst['arch']}/{worst['shape']} "
                           f"{worst['roofline_fraction']:.3f}")
        claims["best"] = (f"{best['arch']}/{best['shape']} "
                          f"{best['roofline_fraction']:.3f}")
    return rows, claims
