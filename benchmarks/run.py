"""Benchmark driver: one function per paper table/figure + the roofline
aggregation. Prints a readable report and overwrites the schema'd
``benchmarks/BENCH_*.json`` perf trajectories in place (the committed,
PR-over-PR diffable record; the old catch-all ``results.json`` scratch
file is gone).

    PYTHONPATH=src python -m benchmarks.run            # all
    PYTHONPATH=src python -m benchmarks.run fig5 area  # subset
    PYTHONPATH=src python -m benchmarks.run manager --predictive
                           # only the gated predictive-SLO rows (CI smoke)
    PYTHONPATH=src python -m benchmarks.run manager --adversarial
                           # only the gated quiet-vs-attack isolation rows
                           # (CI smoke; records the attack trace artifact)
"""
from __future__ import annotations

import json
import sys
from pathlib import Path

from benchmarks.fabric_bench import bench_fabric
from benchmarks.manager_bench import (bench_manager,
                                      bench_manager_adversarial,
                                      bench_manager_predictive)
from benchmarks.moe_bench import bench_moe
from benchmarks.paper_tables import (bench_area, bench_bandwidth_allocation,
                                     bench_fig5_elasticity,
                                     bench_fig6_scaling, bench_kernels_cpu,
                                     bench_latency)
from benchmarks.roofline_bench import bench_roofline
from benchmarks.serve_bench import bench_serve

BENCHES = {
    "fig5": ("Fig 5 — §V-C elasticity use case", bench_fig5_elasticity),
    "bandwidth": ("§V-D — dynamic bandwidth allocation",
                  bench_bandwidth_allocation),
    "latency": ("§V-E — communication overhead", bench_latency),
    "fig6": ("Fig 6 — worst-case latency scaling", bench_fig6_scaling),
    "area": ("Tables I/II — area & power", bench_area),
    "kernels": ("kernel microbenchmarks (CPU)", bench_kernels_cpu),
    "fabric": ("repro.fabric — backend comparison", bench_fabric),
    "manager": ("repro.manager — closed-loop autoscaling scenarios",
                bench_manager),
    "moe": ("models.moe — dispatch impls incl. mesh expert parallelism",
            bench_moe),
    "roofline": ("§Roofline — dry-run aggregation", bench_roofline),
    "serve": ("repro.serve — steady-state decode fast path "
              "(plan cache on/off + reconfiguration storm)", bench_serve),
}

# Stable, machine-readable perf trajectory: one schema-versioned file per
# tracked bench, overwritten in place so successive PRs diff cleanly.
TRAJECTORY_FILES = {"fabric": "BENCH_fabric.json",
                    "manager": "BENCH_manager.json",
                    "moe": "BENCH_moe.json",
                    "serve": "BENCH_serve.json"}


def main(argv=None) -> int:
    args = list(argv if argv is not None else sys.argv[1:])
    predictive = "--predictive" in args
    if predictive:
        args = [a for a in args if a != "--predictive"]
        BENCHES["manager"] = ("repro.manager — predictive-SLO gated rows "
                              "only (CI smoke)", bench_manager_predictive)
    if "--adversarial" in args:
        args = [a for a in args if a != "--adversarial"]
        BENCHES["manager"] = ("repro.manager — quiet-vs-attack isolation "
                              "rows only (CI smoke)",
                              bench_manager_adversarial)
    names = args or list(BENCHES)
    results = {}
    failures = []
    for name in names:
        title, fn = BENCHES[name]
        print(f"\n=== {name}: {title} " + "=" * max(0, 50 - len(title)))
        try:
            rows, claims = fn()
        except Exception as e:              # keep the report going
            failures.append((name, repr(e)))
            print(f"  FAILED: {e!r}")
            continue
        for row in rows[:50]:
            print("  " + ",".join(f"{k}={v}" for k, v in row.items()))
        if len(rows) > 50:
            print(f"  ... ({len(rows)} rows total)")
        print("  claims: " + json.dumps(claims))
        results[name] = {"rows": rows, "claims": claims}

    for name, fname in TRAJECTORY_FILES.items():
        if name not in results:
            continue
        traj = Path(__file__).resolve().parent / fname
        traj.write_text(json.dumps(
            {"schema": 1, "bench": name, **results[name]},
            indent=1, default=str, sort_keys=True))
        print(f"wrote {traj}")
    if failures:
        print("FAILURES:", failures)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
