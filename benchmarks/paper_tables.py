"""One benchmark per paper table/figure.

Each function reproduces one artifact and returns (rows, paper_claims) so
``benchmarks/run.py`` can print the reproduction next to the paper's number.
"""
from __future__ import annotations

import time
from typing import Dict, List, Tuple

import numpy as np


# ----------------------------------------------------------------------
# Fig 5 — §V-C elasticity use case
# ----------------------------------------------------------------------
def bench_fig5_elasticity() -> Tuple[List[dict], Dict[str, float]]:
    from repro.core.hw.system import (ElasticUseCase, PAPER_CASE1_MS,
                                      PAPER_CASE3_MS)
    uc = ElasticUseCase()
    rows = []
    for case, ms in uc.figure5().items():
        res = uc.run_case(case)
        rows.append({"case": case, "total_ms": round(ms, 3),
                     "fpga_ms": round(res.fpga_ms, 4),
                     "cpu_ms": round(res.cpu_ms, 3),
                     "data_ok": res.data_ok})
    return rows, {"paper_case1_ms": PAPER_CASE1_MS,
                  "paper_case3_ms": PAPER_CASE3_MS}


# ----------------------------------------------------------------------
# §V-D — dynamic bandwidth allocation (quota 16 -> 128)
# ----------------------------------------------------------------------
def bench_bandwidth_allocation() -> Tuple[List[dict], Dict[str, float]]:
    from repro.core.hw.system import ElasticUseCase
    uc = ElasticUseCase()
    rows = [{"case": k, "improvement_pct": round(100 * v, 2)}
            for k, v in uc.bandwidth_table().items()]
    return rows, {"paper_1acc_pct": 5.24, "paper_3acc_pct": 6.0}


# ----------------------------------------------------------------------
# §V-E — communication overhead (time-to-grant / completion)
# ----------------------------------------------------------------------
def bench_latency() -> Tuple[List[dict], Dict[str, float]]:
    from repro.core.hw.crossbar import (CrossbarSim, MasterRequest,
                                        best_case_time_to_grant,
                                        request_completion_cc,
                                        worst_case_completion_cc,
                                        worst_case_time_to_grant)
    sim = CrossbarSim()
    for m in (0, 1, 2):
        sim.submit(MasterRequest(cycle=0, master=m, dst_onehot=0b1000,
                                 n_words=8))
    results = sim.run()
    rows = [{
        "best_ttg_cc": best_case_time_to_grant(),
        "completion_8pkt_cc": request_completion_cc(8),
        "worst_ttg_3masters_cc": max(r.time_to_grant for r in results),
        "worst_completion_cc": max(r.completion_latency for r in results),
    }]
    return rows, {"paper_best_ttg": 4, "paper_completion": 13,
                  "paper_worst_ttg": 28, "paper_worst_completion": 37}


# ----------------------------------------------------------------------
# Fig 6 — worst-case latency vs number of PR regions (linear)
# ----------------------------------------------------------------------
def bench_fig6_scaling() -> Tuple[List[dict], Dict[str, float]]:
    from repro.core.hw.area import AreaModel
    curve = AreaModel.worst_case_latency_curve(8)
    rows = [{"n_masters": n, "worst_completion_cc": cc}
            for n, cc in curve.items()]
    diffs = np.diff([cc for cc in curve.values()])
    return rows, {"linear_increment_cc": float(diffs[0]),
                  "is_linear": bool((diffs == diffs[0]).all())}


# ----------------------------------------------------------------------
# Tables I & II — area / power
# ----------------------------------------------------------------------
def bench_area() -> Tuple[List[dict], Dict[str, float]]:
    from repro.core.hw.area import TABLE_I, AreaModel
    m = AreaModel()
    rows = [{"component": k, "lut": v[0], "ff": v[1], "bram": v[2]}
            for k, v in TABLE_I.items()]
    claims = {
        "lut_saving_vs_noc_pct": round(100 * m.lut_saving_vs_noc(), 1),
        "ff_saving_vs_noc_pct": round(100 * m.ff_saving_vs_noc(), 1),
        "power_ratio_vs_noc": m.power_ratio_vs_noc(),
        "lut_overhead_vs_ewb_pct": round(100 * m.lut_overhead_vs_ewb(), 1),
        "ff_saving_vs_ewb_pct": round(100 * m.ff_saving_vs_ewb(), 1),
        "latency_saving_vs_noc_4router_pct":
            round(100 * m.latency_saving_vs_noc(4), 1),
        "paper": "61% LUT, 95% FF, 80x power, +48.6%/-46.4% vs E-WB, 69% cc",
    }
    return rows, claims


# ----------------------------------------------------------------------
# Kernel microbenchmarks (CPU wall time; interpret-mode — correctness
# throughput, not TPU performance; TPU numbers come from the roofline).
# ----------------------------------------------------------------------
def _time_us(fn, *args, n=3, **kw) -> float:
    fn(*args, **kw)                       # compile/warm
    t0 = time.perf_counter()
    for _ in range(n):
        r = fn(*args, **kw)
    try:
        import jax
        jax.block_until_ready(r)
    except Exception:
        pass
    return 1e6 * (time.perf_counter() - t0) / n


def bench_kernels_cpu() -> Tuple[List[dict], Dict[str, str]]:
    import jax
    import jax.numpy as jnp

    from repro.core.registers import CrossbarRegisters
    from repro.core.arbiter import wrr_dispatch_plan
    from repro.kernels.hamming.ops import hamming_encode
    from repro.models.attention import attention_prefill

    rows = []
    ks = jax.random.split(jax.random.key(0), 4)

    # crossbar plan (jnp production path)
    dst = jax.random.randint(ks[0], (4096,), 0, 8)
    src = jax.random.randint(ks[1], (4096,), 0, 8)
    regs = CrossbarRegisters.create(8, capacity=1024)
    f = jax.jit(lambda d, s: wrr_dispatch_plan(d, s, regs).counts)
    rows.append({"name": "wrr_dispatch_plan_4096pkts",
                 "us_per_call": round(_time_us(f, dst, src), 1)})

    # hamming 16 KB use case
    data = jnp.asarray(np.arange(4096, dtype=np.uint32))
    rows.append({"name": "hamming_encode_16KB",
                 "us_per_call": round(_time_us(hamming_encode, data), 1)})

    # chunked attention 1k
    q = jax.random.normal(ks[2], (1, 1024, 4, 64), jnp.float32)
    kv = jax.random.normal(ks[3], (1, 1024, 2, 64), jnp.float32)
    f2 = jax.jit(lambda q, k, v: attention_prefill(q, k, v, causal=True))
    rows.append({"name": "attention_prefill_1k",
                 "us_per_call": round(_time_us(f2, q, kv, kv), 1)})
    return rows, {"note": "CPU wall time; TPU perf is §Roofline's job"}
