"""Backend-comparison bench for the ``repro.fabric`` data plane.

Times ``plan`` and the fused ``transfer`` round-trip per backend over a
(T x n_ports) grid and reports tokens/s, so backend regressions show up in
the machine-readable ``BENCH_fabric.json`` trajectory (written by
``benchmarks/run.py``).  On this CPU container the pallas backend runs in
interpret mode — correctness throughput, not TPU performance — and the
sharded backend needs >1 local device, so its trajectory lives in the
``moe`` bench (``BENCH_moe.json``), which subprocesses onto a forced
4-device topology.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np

from benchmarks.timing import time_us

# Small grid: this doubles as the CI smoke bench, so it must stay fast.
SHAPES = [(256, 4), (1024, 8)]          # (T packets, n_ports)
D = 64                                   # payload width
CAPACITY = 512


def bench_fabric() -> Tuple[List[dict], Dict[str, str]]:
    import jax
    import jax.numpy as jnp

    from repro.core.registers import CrossbarRegisters
    from repro.fabric import Fabric

    rows = []
    rng = np.random.default_rng(0)
    backends = ["reference", "pallas"]
    for T, n_ports in SHAPES:
        regs = CrossbarRegisters.create(n_ports, capacity=CAPACITY)
        dst = jnp.asarray(rng.integers(0, n_ports, T), jnp.int32)
        src = jnp.asarray(rng.integers(0, n_ports, T), jnp.int32)
        x = jnp.asarray(rng.standard_normal((T, D)), jnp.float32)
        base_plan = None
        for name in backends:
            fabric = Fabric(regs, backend=name, capacity=CAPACITY)
            plan_us = time_us(lambda d, s, f=fabric: f.plan(d, s).counts,
                              dst, src)
            transfer_us = time_us(
                lambda xx, d, s, f=fabric: f.transfer(xx, d, s)[0],
                x, dst, src)
            plan = fabric.plan(dst, src)
            counts = np.asarray(plan.counts)
            if base_plan is None:
                base_plan = counts
            rows.append({
                "backend": name, "T": T, "n_ports": n_ports, "D": D,
                "plan_us": round(plan_us, 1),
                "transfer_us": round(transfer_us, 1),
                "tokens_per_s": round(T / (transfer_us * 1e-6)),
                "granted": int(counts.sum()),
                "plan_equal_reference": bool(
                    np.array_equal(counts, base_plan)),
            })
        # Debug-off guard row: `debug` is resolved to a trace-time constant
        # at Fabric construction, so an explicit debug=False fabric must run
        # the *same* compiled transfer as a plain one — the sanitizer layer
        # (docs/invariants.md) is free when off.  check_bench_regression.py
        # gates overhead_ratio within this file, so the check is
        # machine-neutral.
        plain = Fabric(regs, backend="reference", capacity=CAPACITY)
        off = Fabric(regs, backend="reference", capacity=CAPACITY,
                     debug=False)
        plain_us = time_us(
            lambda xx, d, s, f=plain: f.transfer(xx, d, s)[0], x, dst, src)
        off_us = time_us(
            lambda xx, d, s, f=off: f.transfer(xx, d, s)[0], x, dst, src)
        y_plain = plain.transfer(x, dst, src)[0]
        y_off = off.transfer(x, dst, src)[0]
        rows.append({
            "backend": "debug_off_guard", "T": T, "n_ports": n_ports,
            "D": D,
            "transfer_us": round(off_us, 1),
            "plain_transfer_us": round(plain_us, 1),
            "overhead_ratio": round(off_us / plain_us, 3),
            "bit_identical_to_plain": bool(
                np.array_equal(np.asarray(y_plain), np.asarray(y_off))),
        })
        # Backward-at-gather-cost guard row: grad of the transfer round
        # trip rides the custom VJP (backward = gather/scatter-add over
        # the same flat address route), so a full value_and_grad must
        # price like a small multiple of the forward — NOT like a dense
        # [T, S*C] routing matmul — and its compiled HLO must contain no
        # [T, n_ports*CAPACITY]-sized intermediate.  Both are within-file
        # (machine-neutral); check_bench_regression.py gates them.
        from repro.launch.roofline import dense_routing_bytes

        probe = jnp.asarray(rng.standard_normal((T, D)), jnp.float32)

        def tloss(xx, d, s, f=plain, p=probe):
            return jnp.sum(f.transfer(xx, d, s)[0] * p)

        grad_fn = jax.jit(jax.value_and_grad(tloss))
        fwd_us = time_us(
            lambda xx, d, s, f=plain: f.transfer(xx, d, s)[0], x, dst, src)
        grad_us = time_us(grad_fn, x, dst, src)
        hlo = grad_fn.lower(x, dst, src).compile().as_text()
        rows.append({
            "backend": "bwd_vs_fwd", "T": T, "n_ports": n_ports, "D": D,
            "forward_us": round(fwd_us, 1),
            "grad_us": round(grad_us, 1),
            "bwd_vs_fwd": round(grad_us / fwd_us, 3),
            "bwd_dense_routing_bytes": dense_routing_bytes(
                hlo, T, n_ports * CAPACITY),
        })
    claims = {
        "note": ("CPU wall time (pallas in interpret mode); the trajectory "
                 "tracks relative backend cost, TPU perf is the roofline's "
                 "job"),
        "timing": "warmup + median of 5 device-synced samples",
        "device_count": str(jax.device_count()),
        "sharded": "see BENCH_moe.json (forced multi-device subprocess)"
        if jax.device_count() < 2 else "see rows",
        "debug_off_guard": ("explicit debug=False vs plain Fabric on the "
                            "reference backend; overhead_ratio ~1.0 and "
                            "bit-identical outputs prove the checkify "
                            "sanitizer costs nothing when off"),
        "bwd_vs_fwd": ("value_and_grad of the transfer round trip vs its "
                       "forward, reference backend; the custom VJP keeps "
                       "the backward address-routed, so the ratio stays a "
                       "small multiple of 1 and bwd_dense_routing_bytes "
                       "is 0 — no dense [T, S*C] tensor in the grad HLO"),
    }
    return rows, claims
