"""MoE dispatch-implementation bench: dense vs gather vs fabric backends
vs mesh-sharded expert parallelism, over a T x experts grid.

Rows land in the machine-readable ``BENCH_moe.json`` trajectory (written
by ``benchmarks/run.py``), so dispatch-path regressions show up PR over
PR.  The single-device impls run in-process; the ``sharded`` rows run in
a subprocess with a forced 4-device CPU topology (the repo convention —
jax pins the device count at first init).  CPU wall time: the trajectory
tracks *relative* dispatch cost, TPU performance is the roofline's job.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
from pathlib import Path
from typing import Dict, List, Tuple

import numpy as np

from benchmarks.timing import time_us

# Small grid — this doubles as the CI smoke bench.
SHAPES = [(256, 4), (512, 8)]            # (T tokens, n_experts)
D, D_FF = 32, 64
TOP_K = 2
CAPACITY_FACTOR = 2.0                    # ample: all impls agree exactly
IMPLS = ["dense", "gather", "reference", "pallas"]
N_SHARDS = 4

_SHARDED_CODE = """
import functools, json, sys
import numpy as np, jax, jax.numpy as jnp
sys.path.insert(0, {bench_dir!r})
from timing import time_us
from repro.models.common import init_params
from repro.models.config import MoEConfig
from repro.models.moe import moe_defs, moe_forward_sharded, expert_capacity

for T, E in {shapes}:
    moe = MoEConfig(n_experts=E, top_k={top_k},
                    capacity_factor={capacity_factor})
    params = init_params(moe_defs({d}, {d_ff}, moe, "swiglu"),
                         jax.random.key(0), jnp.float32)
    B = {n_shards} * 2
    x = jax.random.normal(jax.random.key(1), (B, T // B, {d}))
    mesh = jax.make_mesh(({n_shards},), ("expert",))
    cap = expert_capacity(T, moe)
    fn = jax.jit(lambda p, xx: moe_forward_sharded(
        p, xx, moe, "swiglu", mesh=mesh, capacity=cap))
    us = time_us(fn, params, x)
    y, stats = fn(params, x)
    print(json.dumps({{
        "impl": "sharded", "T": T, "E": E, "d": {d},
        "forward_us": round(us, 1),
        "tokens_per_s": round(T / (us * 1e-6)),
        "dropped": int(stats["dropped"]),
        "remote_packets": int(stats["remote_packets"]),
        "local_packets": int(stats["local_packets"]),
    }}))
print("MOE_BENCH_SHARDED_DONE")
"""


def _sharded_rows() -> Tuple[List[dict], str]:
    """Run the sharded impl on a forced multi-device topology."""
    code = _SHARDED_CODE.format(shapes=SHAPES, top_k=TOP_K,
                                capacity_factor=CAPACITY_FACTOR, d=D,
                                d_ff=D_FF, n_shards=N_SHARDS,
                                bench_dir=str(
                                    Path(__file__).resolve().parent))
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count"
                        f"={N_SHARDS}")
    src = Path(__file__).resolve().parent.parent / "src"
    env["PYTHONPATH"] = str(src) + os.pathsep + env.get("PYTHONPATH", "")
    try:
        res = subprocess.run([sys.executable, "-c", code], env=env,
                             capture_output=True, text=True, timeout=600)
    except subprocess.TimeoutExpired:
        return [], "sharded: subprocess timed out"
    if res.returncode != 0 or "MOE_BENCH_SHARDED_DONE" not in res.stdout:
        return [], f"sharded: subprocess failed: {res.stderr[-400:]}"
    rows = [json.loads(line) for line in res.stdout.splitlines()
            if line.startswith("{")]
    return rows, f"forced {N_SHARDS}-device CPU topology (subprocess)"


def bench_moe() -> Tuple[List[dict], Dict[str, str]]:
    import jax
    import jax.numpy as jnp

    from repro.models.common import init_params
    from repro.models.config import MoEConfig
    from repro.models.moe import moe_apply, moe_defs

    rows: List[dict] = []
    for T, E in SHAPES:
        moe = MoEConfig(n_experts=E, top_k=TOP_K,
                        capacity_factor=CAPACITY_FACTOR)
        params = init_params(moe_defs(D, D_FF, moe, "swiglu"),
                             jax.random.key(0), jnp.float32)
        x = jax.random.normal(jax.random.key(1), (8, T // 8, D))
        base = None
        for impl in IMPLS:
            fn = jax.jit(lambda p, xx, i=impl: moe_apply(
                p, xx, moe, "swiglu", group_size=T, dispatch_impl=i))
            us = time_us(fn, params, x)
            y, stats = fn(params, x)
            y = np.asarray(y)
            if base is None:
                base = y
            rows.append({
                "impl": impl, "T": T, "E": E, "d": D,
                "forward_us": round(us, 1),
                "tokens_per_s": round(T / (us * 1e-6)),
                "dropped": int(stats["dropped"]),
                "agrees_dense": bool(np.allclose(y, base, atol=2e-4)),
            })
    sharded, sharded_note = _sharded_rows()
    rows.extend(sharded)
    # Gather-relative cost per (T, E): the inline gather baseline is the
    # floor a fabric-routed impl should approach — the CI gate reads this.
    gather_us = {(r["T"], r["E"]): r["forward_us"] for r in rows
                 if r["impl"] == "gather"}
    for r in rows:
        floor = gather_us.get((r["T"], r["E"]))
        if floor:
            r["vs_gather"] = round(r["forward_us"] / floor, 2)
    claims = {
        "note": ("CPU wall time (pallas in interpret mode); ample "
                 "capacity so every impl routes identically"),
        "timing": "warmup + median of 5 device-synced samples",
        "vs_gather": ("forward_us relative to the inline gather baseline "
                      "at the same (T, E)"),
        "device_count": str(jax.device_count()),
        "sharded": sharded_note,
    }
    return rows, claims
