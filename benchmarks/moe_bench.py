"""MoE dispatch-implementation bench: dense vs gather vs fabric backends
vs mesh-sharded expert parallelism, over a T x experts grid.

Rows land in the machine-readable ``BENCH_moe.json`` trajectory (written
by ``benchmarks/run.py``), so dispatch-path regressions show up PR over
PR.  The single-device impls run in-process; the ``sharded`` rows run in
a subprocess with a forced 4-device CPU topology (the repo convention —
jax pins the device count at first init).  CPU wall time: the trajectory
tracks *relative* dispatch cost, TPU performance is the roofline's job.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
from pathlib import Path
from typing import Dict, List, Tuple

import numpy as np

from benchmarks.timing import time_us

# Small grid — this doubles as the CI smoke bench.
SHAPES = [(256, 4), (512, 8)]            # (T tokens, n_experts)
D, D_FF = 32, 64
TOP_K = 2
CAPACITY_FACTOR = 2.0                    # ample: all impls agree exactly
IMPLS = ["dense", "gather", "reference", "pallas"]
GRAD_SHAPE = (512, 8)                    # (T, E) for the train-grad rows
GRAD_IMPLS = ["gather", "dense", "reference", "pallas"]
N_SHARDS = 4

_SHARDED_CODE = """
import functools, json, sys
import numpy as np, jax, jax.numpy as jnp
sys.path.insert(0, {bench_dir!r})
from timing import time_us
from repro.models.common import init_params
from repro.models.config import MoEConfig
from repro.models.moe import moe_defs, moe_forward_sharded, expert_capacity

for T, E in {shapes}:
    moe = MoEConfig(n_experts=E, top_k={top_k},
                    capacity_factor={capacity_factor})
    params = init_params(moe_defs({d}, {d_ff}, moe, "swiglu"),
                         jax.random.key(0), jnp.float32)
    B = {n_shards} * 2
    x = jax.random.normal(jax.random.key(1), (B, T // B, {d}))
    mesh = jax.make_mesh(({n_shards},), ("expert",))
    cap = expert_capacity(T, moe)
    fn = jax.jit(lambda p, xx: moe_forward_sharded(
        p, xx, moe, "swiglu", mesh=mesh, capacity=cap))
    us = time_us(fn, params, x)
    y, stats = fn(params, x)
    print(json.dumps({{
        "impl": "sharded", "T": T, "E": E, "d": {d},
        "forward_us": round(us, 1),
        "tokens_per_s": round(T / (us * 1e-6)),
        "dropped": int(stats["dropped"]),
        "remote_packets": int(stats["remote_packets"]),
        "local_packets": int(stats["local_packets"]),
    }}))
print("MOE_BENCH_SHARDED_DONE")
"""


def _sharded_rows() -> Tuple[List[dict], str]:
    """Run the sharded impl on a forced multi-device topology."""
    code = _SHARDED_CODE.format(shapes=SHAPES, top_k=TOP_K,
                                capacity_factor=CAPACITY_FACTOR, d=D,
                                d_ff=D_FF, n_shards=N_SHARDS,
                                bench_dir=str(
                                    Path(__file__).resolve().parent))
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count"
                        f"={N_SHARDS}")
    src = Path(__file__).resolve().parent.parent / "src"
    env["PYTHONPATH"] = str(src) + os.pathsep + env.get("PYTHONPATH", "")
    try:
        res = subprocess.run([sys.executable, "-c", code], env=env,
                             capture_output=True, text=True, timeout=600)
    except subprocess.TimeoutExpired:
        return [], "sharded: subprocess timed out"
    if res.returncode != 0 or "MOE_BENCH_SHARDED_DONE" not in res.stdout:
        return [], f"sharded: subprocess failed: {res.stderr[-400:]}"
    rows = [json.loads(line) for line in res.stdout.splitlines()
            if line.startswith("{")]
    return rows, f"forced {N_SHARDS}-device CPU topology (subprocess)"


def _train_grad_rows() -> List[dict]:
    """Backward-pass rows: one optimizer-style grad per dispatch impl at
    ``GRAD_SHAPE``.  The fabric-routed grad rides the custom VJP (backward
    replays the flat ``dst*C+slot`` address route), so it must price like
    the inline-gather grad, not like the dense one-hot grad — the CI gate
    reads ``vs_gather_grad`` within this file (machine-neutral) and pins
    ``bwd_dense_routing_bytes == 0``: the compiled backward HLO contains
    no [T*k, E*C]-sized routing intermediate (the dense rows show the
    detector firing on the formulation that does materialize one)."""
    import functools

    import jax
    import jax.numpy as jnp

    from repro.launch.roofline import dense_routing_bytes
    from repro.models.common import init_params
    from repro.models.config import MoEConfig
    from repro.models.moe import expert_capacity, moe_apply, moe_defs

    T, E = GRAD_SHAPE
    moe = MoEConfig(n_experts=E, top_k=TOP_K,
                    capacity_factor=CAPACITY_FACTOR)
    params = init_params(moe_defs(D, D_FF, moe, "swiglu"),
                         jax.random.key(0), jnp.float32)
    x = jax.random.normal(jax.random.key(1), (8, T // 8, D))
    cap = expert_capacity(T, moe)

    def loss(p, xx, impl):
        y, stats = moe_apply(p, xx, moe, "swiglu", group_size=T,
                             dispatch_impl=impl)
        return jnp.sum(y * y) + stats["aux_loss"]

    rows: List[dict] = []
    base = None
    for impl in GRAD_IMPLS:
        fwd = jax.jit(functools.partial(
            lambda p, xx, i: loss(p, xx, i), i=impl))
        fn = jax.jit(functools.partial(
            lambda p, xx, i: jax.grad(loss)(p, xx, i), i=impl))
        fwd_us = time_us(fwd, params, x)
        us = time_us(fn, params, x)
        hlo = fn.lower(params, x).compile().as_text()
        grads = jax.tree.leaves(fn(params, x))
        if base is None:
            base = grads                 # first impl (gather) is the probe
        agrees = all(np.allclose(np.asarray(g), np.asarray(b),
                                 rtol=2e-4, atol=2e-5)
                     for g, b in zip(grads, base))
        rows.append({
            "mode": "train_grad", "impl": impl, "T": T, "E": E, "d": D,
            "forward_loss_us": round(fwd_us, 1),
            "grad_us": round(us, 1),
            "tokens_per_s": round(T / (us * 1e-6)),
            # packet count is T * top_k: each token is routed k times
            "bwd_dense_routing_bytes": dense_routing_bytes(
                hlo, T * TOP_K, E * cap),
            "grad_agrees": agrees,
        })
    gfloor = next(r["grad_us"] for r in rows if r["impl"] == "gather")
    ffloor = next(r["forward_loss_us"] for r in rows
                  if r["impl"] == "gather")
    for r in rows:
        r["vs_gather_grad"] = round(r["grad_us"] / gfloor, 3)
        r["vs_gather_fwd"] = round(r["forward_loss_us"] / ffloor, 3)
        # The gated claim: whatever forward overhead an impl carries
        # (WRR plan arbitration, interpret-mode kernels), its *backward*
        # adds none on top — grad ratio stays within the forward ratio.
        r["bwd_overhead"] = round(r["vs_gather_grad"]
                                  / max(r["vs_gather_fwd"], 1e-9), 3)
    return rows


def bench_moe() -> Tuple[List[dict], Dict[str, str]]:
    import jax
    import jax.numpy as jnp

    from repro.models.common import init_params
    from repro.models.config import MoEConfig
    from repro.models.moe import moe_apply, moe_defs

    rows: List[dict] = []
    for T, E in SHAPES:
        moe = MoEConfig(n_experts=E, top_k=TOP_K,
                        capacity_factor=CAPACITY_FACTOR)
        params = init_params(moe_defs(D, D_FF, moe, "swiglu"),
                             jax.random.key(0), jnp.float32)
        x = jax.random.normal(jax.random.key(1), (8, T // 8, D))
        base = None
        for impl in IMPLS:
            fn = jax.jit(lambda p, xx, i=impl: moe_apply(
                p, xx, moe, "swiglu", group_size=T, dispatch_impl=i))
            us = time_us(fn, params, x)
            y, stats = fn(params, x)
            y = np.asarray(y)
            if base is None:
                base = y
            rows.append({
                "impl": impl, "T": T, "E": E, "d": D,
                "forward_us": round(us, 1),
                "tokens_per_s": round(T / (us * 1e-6)),
                "dropped": int(stats["dropped"]),
                "agrees_dense": bool(np.allclose(y, base, atol=2e-4)),
            })
    sharded, sharded_note = _sharded_rows()
    rows.extend(sharded)
    rows.extend(_train_grad_rows())
    # Gather-relative cost per (T, E): the inline gather baseline is the
    # floor a fabric-routed impl should approach — the CI gate reads this.
    gather_us = {(r["T"], r["E"]): r["forward_us"] for r in rows
                 if r["impl"] == "gather" and "forward_us" in r}
    for r in rows:
        floor = gather_us.get((r["T"], r["E"]))
        if floor and "forward_us" in r:
            r["vs_gather"] = round(r["forward_us"] / floor, 2)
    claims = {
        "note": ("CPU wall time (pallas in interpret mode); ample "
                 "capacity so every impl routes identically"),
        "timing": "warmup + median of 5 device-synced samples",
        "vs_gather": ("forward_us relative to the inline gather baseline "
                      "at the same (T, E)"),
        "train_grad": ("one jit(grad(loss)) step per dispatch impl at "
                       f"(T, E)={GRAD_SHAPE}; the fabric-routed grad rides "
                       "the custom VJP so bwd_overhead (grad-vs-gather "
                       "normalized by the impl's own forward-vs-gather) "
                       "must stay near 1.0 and bwd_dense_routing_bytes at "
                       "0 (no dense [T*k, E*C] routing tensor in the "
                       "backward HLO) — gated by "
                       "tools/check_bench_regression.py --moe-json"),
        "device_count": str(jax.device_count()),
        "sharded": sharded_note,
    }
    return rows, claims
