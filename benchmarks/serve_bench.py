"""Steady-state serving bench for ``repro.serve`` + the fabric plan cache.

Two scenarios over the seeded :class:`~repro.serve.ServeHarness`:

- **steady_state** — 2048 front-loaded streams through 1024 concurrent
  decode slots (reference backend), once with the fabric plan cache on and
  once off, same seed.  The gated number is the median *pure-decode* tick
  (no admission, no reconfiguration — the path the epoch-keyed cache
  accelerates) and the sha256 digest of every completion: the cached run
  must be bit-identical and at most half the uncached tick.
- **storm** — heavy-tailed arrivals with FailRegion / heal / Shrink / Grow
  posted mid-run: every post bumps the register epoch and must invalidate
  the cache (counted), while ``fabric_retraces`` stays at 1 — replanning
  reuses the compiled program, the cache only skips re-*executing* it.

Wall-time rows are machine-relative, so ``tools/check_bench_regression.py``
gates the *within-file* cached/uncached ratio (and the pure-function rows:
digests equal, retraces == 1) rather than absolute microseconds.  GC is
paused around the timed runs; each configuration takes the
best-median-of-3 repeats, standard microbenchmark discipline.
"""
from __future__ import annotations

import gc
from typing import Dict, List, Tuple

STEADY_STREAMS = 2048
STEADY_SLOTS = 1024
STEADY_MAX_NEW = 48
STORM_STREAMS = 2048
STORM_SLOTS = 256
SEED = 11
REPEATS = 3


def _server(plan_cache: bool, n_slots: int):
    from repro.core.elastic import Region
    from repro.core.module import ModuleFootprint
    from repro.serve import SeededEngine
    from repro.shell import Shell
    from repro.shell.server import ElasticServer

    GB = 1 << 30
    shell = Shell([Region(rid=i, n_chips=8, hbm_bytes=8 * GB)
                   for i in range(4)])
    shell.submit("svc", [ModuleFootprint(GB, 1e9, 4096)] * 2, app_id=0)
    server = ElasticServer(shell, n_slots=n_slots, plan_cache=plan_cache)
    server.register_engine(0, SeededEngine(seed=SEED))
    return server


def _best_of(arrivals, plan_cache: bool, n_slots: int, reconfigs=()):
    """Fresh server per repeat; keep the repeat with the best median
    steady tick (wall-time noise is one-sided — slow outliers only)."""
    from repro.serve import ServeHarness

    best = None
    for _ in range(REPEATS):
        report = ServeHarness(_server(plan_cache, n_slots), arrivals,
                              reconfigs=reconfigs).run()
        if (best is None
                or report.steady_tick_p50_us < best.steady_tick_p50_us):
            best = report
    return best


def _steady_row(mode: str, cache: str, r) -> dict:
    return {"mode": mode, "cache": cache, "streams": r.n_streams,
            "slots": r.n_slots, "ticks": r.ticks,
            "steady_ticks": r.steady_ticks, "tokens": r.tokens,
            "decode_tick_p50_us": round(r.steady_tick_p50_us, 1),
            "decode_tick_p99_us": round(r.steady_tick_p99_us, 1),
            "tokens_per_s": round(r.tokens_per_s),
            "plan_cache_hit_rate": round(r.plan_cache_hit_rate, 3),
            "fabric_retraces": r.fabric_retraces,
            "token_digest": r.token_digest[:16]}


def bench_serve() -> Tuple[List[dict], Dict[str, str]]:
    from repro.serve import (ReconfigEvent, front_loaded_arrivals,
                             heavy_tailed_arrivals)

    steady = front_loaded_arrivals(STEADY_STREAMS, seed=SEED,
                                   max_new=STEADY_MAX_NEW)
    bursty = heavy_tailed_arrivals(STORM_STREAMS, seed=SEED,
                                   mean_gap_ticks=0.1)
    storm_script = lambda: [
        ReconfigEvent(20, lambda sh: sh.fail_region(2), "fail R2"),
        ReconfigEvent(35, lambda sh: sh.heal_region(2), "heal R2"),
        ReconfigEvent(50, lambda sh: sh.shrink("svc", 1), "shrink svc"),
        ReconfigEvent(65, lambda sh: sh.grow("svc", 1), "grow svc"),
    ]

    gc_was_enabled = gc.isenabled()
    gc.disable()
    try:
        on = _best_of(steady, True, STEADY_SLOTS)
        off = _best_of(steady, False, STEADY_SLOTS)
        storm_on = _best_of(bursty, True, STORM_SLOTS,
                            reconfigs=storm_script())
        storm_off = _best_of(bursty, False, STORM_SLOTS,
                             reconfigs=storm_script())
    finally:
        if gc_was_enabled:
            gc.enable()

    ratio = (on.steady_tick_p50_us / off.steady_tick_p50_us
             if off.steady_tick_p50_us else 0.0)
    rows = [
        _steady_row("steady_state", "on", on),
        _steady_row("steady_state", "off", off),
        {"mode": "steady_state_ratio",
         "concurrent_streams": STEADY_SLOTS,
         "cached_over_uncached": round(ratio, 3),
         "bit_identical": on.token_digest == off.token_digest},
        {"mode": "storm", "cache": "on", "streams": storm_on.n_streams,
         "slots": storm_on.n_slots, "completions": storm_on.completions,
         "tokens": storm_on.tokens, "reconfigs": storm_on.reconfigs,
         "fabric_retraces": storm_on.fabric_retraces,
         "plan_cache_invalidations": storm_on.plan_cache_invalidations,
         "plan_cache_hit_rate": round(storm_on.plan_cache_hit_rate, 3),
         "admission_p50_ticks": storm_on.admission_p50_ticks,
         "admission_p99_ticks": storm_on.admission_p99_ticks,
         "token_digest": storm_on.token_digest[:16]},
        {"mode": "storm_identity",
         "bit_identical":
             storm_on.token_digest == storm_off.token_digest,
         "reconfigs": storm_on.reconfigs,
         "fabric_retraces": storm_on.fabric_retraces},
    ]
    claims = {
        "bit_identical": ("cached and uncached runs produce sha256-equal "
                          "completion streams in both scenarios — the "
                          "cache is a pure memo, never a semantic change"),
        "steady_state": (f"median pure-decode tick with the plan cache is "
                         f"{ratio:.2f}x the uncached tick at "
                         f"{STEADY_SLOTS} concurrent streams "
                         f"(gate: <= 0.75, see check_bench_regression)"),
        "zero_retrace": ("fabric_retraces stays 1 across every mid-run "
                         "FailRegion/heal/Shrink/Grow — epoch bumps "
                         "invalidate cache *entries*, compiled programs "
                         "are reused"),
        "deterministic": ("counting rows (tokens, completions, digests, "
                          "invalidations) are pure functions of the seed"),
    }
    return rows, claims
