"""Shared wall-clock timing for the benchmark suite.

One discipline for every timed bench: a warmup pass (compile + caches),
then k independently-synced samples, report the **median**.  Every sample
brackets a full ``jax.block_until_ready`` on the result pytree, so async
dispatch can't smear one iteration's device work into the next — the
single-mean-over-a-hot-loop the benches used before let the cheapest
sample dominate and turned the ``BENCH_*.json`` trajectories into noise.
"""
from __future__ import annotations

import statistics
import time


def time_us(fn, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall time of ``fn(*args)`` in microseconds.

    ``warmup`` un-timed calls absorb compilation; each of the ``iters``
    timed calls is individually synchronized with ``block_until_ready``.
    """
    import jax

    for _ in range(max(1, warmup)):
        jax.block_until_ready(fn(*args))
    samples = []
    for _ in range(max(1, iters)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        samples.append(1e6 * (time.perf_counter() - t0))
    return statistics.median(samples)
